"""Paged decode states: KV in a shared page pool, addressed by block table.

The dense decode path (``lm.decode_step``) carries one ``KVCache`` per
attention layer with the batch baked into the tensor — moving a sequence
between batch slots is a per-layer tensor copy.  This module carries the
same model through a *paged* layout instead:

* each attention layer owns a KV **pool** ``(num_pages, page_size, K, hd)``
  (reps-stacked like every other scanned state, so shape is
  ``(reps, num_pages, page_size, K, hd)``),
* all layers share ONE **block table** ``(B, pages_per_slot)`` int32 and one
  **lengths** vector ``(B,)`` — every layer writes the same positions, so
  per-layer tables would be copies of each other,
* page 0 is the **trash page**: free slots (``lengths == 0`` after an
  extract) keep decoding into it through their zeroed table rows, exactly
  as the dense path keeps advancing freed slots — their output is garbage
  and discarded either way.  Real pages start at index 1.

Moving a sequence is then a block-table edit (host-side metadata); the
pools never move.  Recurrent blocks (rglru/rwkv6) have O(1) fixed-size
states with a plain batch axis and route through ``lm.block_step``
unchanged — the paged layout only reinterprets attention KV.

``decode_step`` here is jit-compatible with a stable signature
``(params, token, states, tables, lengths)``; with ``use_kernel`` the
attention read goes through the Pallas ``kernels.paged_attention`` kernel,
otherwise through the gather oracle ``kernels.ref.paged_sdpa_ref`` — whose
math is column-for-column the dense ``decode_attention`` masked softmax,
which is what makes the paged serving backend stream-identical to the
dense one (see the oracle's docstring).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import lm
from .config import ModelConfig
from .layers import embed, rmsnorm, rope, unembed
from .recurrent import init_lru_state, init_rwkv_state


class PagedKV(NamedTuple):
    k: jax.Array          # (num_pages, page_size, K, hd)
    v: jax.Array          # (num_pages, page_size, K, hd)


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Real HBM bytes ONE pool page index costs across the whole model.

    Every attention layer owns its own K and V pool (reps-stacked per
    stage), and all of them are sized by the same ``num_pages`` — so one
    more page index buys ``page_size`` KV positions in *every* layer:

        2 (k+v) x n_attn_layers x page_size x n_kv_heads x hd x itemsize

    This is the ruler that converts an HBM byte budget into a pool size
    (``PagedJaxModelBackend(hbm_bytes=...)``): pool capacity ==
    budget // page bytes, instead of the ``slack_slots`` guess.
    Attention-free models (pure recurrent stacks) price to 0 — they own
    no pools and any budget sizes an empty layout.
    """
    n_attn = sum(reps * sum(1 for kind in pat if kind == "attn")
                 for pat, reps in lm._stages(cfg))
    itemsize = jnp.dtype(cfg.cdtype).itemsize
    return 2 * n_attn * page_size * cfg.n_kv_heads * cfg.hd * itemsize


def init_paged_state(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int):
    """Decode states with paged attention KV.

    Shaped like ``lm.init_state`` (list per stage, tuple per pattern
    position, leaves reps-stacked at axis 0) except attention positions
    hold a :class:`PagedKV` pool — batch-free: slots only exist in the
    block table.  ``batch`` still sizes the recurrent states.
    """
    assert not cfg.enc_layers, "paged decode: decoder-only models"

    def stk(make, reps):
        one = make()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one)

    def mk_pool():
        K = cfg.n_kv_heads
        return PagedKV(
            k=jnp.zeros((num_pages, page_size, K, cfg.hd), cfg.cdtype),
            v=jnp.zeros((num_pages, page_size, K, cfg.hd), cfg.cdtype))

    states = []
    for pat, reps in lm._stages(cfg):
        st = []
        for kind in pat:
            if kind == "attn":
                st.append(stk(mk_pool, reps))
            elif kind == "rec":
                st.append(stk(lambda: init_lru_state(cfg, batch), reps))
            elif kind == "rwkv":
                st.append(stk(lambda: init_rwkv_state(cfg, batch), reps))
            else:
                raise ValueError(f"paged decode: unsupported block {kind!r}")
        states.append(tuple(st))
    return states


def paged_decode_attention(params, x, st: PagedKV, tables, lengths,
                           cfg: ModelConfig, *, use_kernel: bool = False):
    """One-token attention against a paged pool.

    Mirrors ``attention.decode_attention``: project q/k/v, rope at
    position ``lengths`` (tokens seen so far), scatter the new K/V into
    the slot's current page at ``(tables[b, lengths // ps], lengths % ps)``,
    then attend over ``lengths + 1`` valid positions.  Free slots
    (zeroed table rows) scatter into the trash page.
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    knew = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    vnew = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    pos = lengths                                       # (B,) int32
    q = rope(q, pos[:, None], cfg.rope_theta, cfg.rope_fraction)
    knew = rope(knew, pos[:, None], cfg.rope_theta, cfg.rope_fraction)

    page_size = st.k.shape[1]
    npages = tables.shape[1]
    page = tables[jnp.arange(B),
                  jnp.clip(pos // page_size, 0, npages - 1)]
    off = pos % page_size
    k_pool = st.k.at[page, off].set(knew[:, 0].astype(st.k.dtype))
    v_pool = st.v.at[page, off].set(vnew[:, 0].astype(st.v.dtype))

    scale = cfg.hd ** -0.5
    H, K = cfg.n_heads, cfg.n_kv_heads
    g = H // K
    qk = q[:, 0].reshape(B, K, g, cfg.hd)
    if use_kernel:
        from repro.kernels import paged_attention
        out = paged_attention.paged_attn(qk, k_pool, v_pool, tables,
                                         pos + 1, window=cfg.window,
                                         scale=scale)
    else:
        from repro.kernels import ref
        out = ref.paged_sdpa_ref(qk, k_pool, v_pool, tables, pos + 1,
                                 window=cfg.window, scale=scale)
    out = out.reshape(B, 1, H, cfg.hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, PagedKV(k=k_pool, v=v_pool)


def _paged_attn_block_step(params, x, st, tables, lengths, cfg, *,
                           use_kernel: bool):
    """The ``lm.block_step`` attn branch with paged attention swapped in."""
    h, new = paged_decode_attention(params["attn"],
                                    rmsnorm(params["ln1"], x), st, tables,
                                    lengths, cfg, use_kernel=use_kernel)
    x = x + h
    h, _ = lm._ffn_apply(params["ffn"], rmsnorm(params["ln2"], x), cfg)
    return x + h, new


def _scan_stage_step(params_stage, x, states, tables, lengths, cfg, pat, *,
                     use_kernel: bool):
    def body(x, inp):
        layer_params, layer_states = inp
        new_states = []
        for pi, kind in enumerate(pat):
            p = layer_params[f"b{pi}_{kind}"]
            if kind == "attn":
                x, ns = _paged_attn_block_step(p, x, layer_states[pi],
                                               tables, lengths, cfg,
                                               use_kernel=use_kernel)
            else:
                x, ns = lm.block_step(p, x, layer_states[pi], cfg, kind)
            new_states.append(ns)
        return x, tuple(new_states)

    return jax.lax.scan(body, x, (params_stage, states))


def decode_step(params, token: jax.Array, states, tables, lengths,
                cfg: ModelConfig, *, use_kernel: bool = False):
    """token (B,1) int32 → (logits (B,V), new states).

    ``tables``/``lengths`` are inputs, not state: the host (the serving
    backend) owns page allocation and advances lengths — the model only
    reads through them.  Every batch row's position advances each call,
    occupied or not, exactly like the dense path's ``pos + 1``.
    """
    h = embed(params["embed"], token).astype(cfg.cdtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    new_states = []
    for si, (pat, _) in enumerate(lm._stages(cfg)):
        h, ns = _scan_stage_step(params[f"stage{si}"], h, states[si],
                                 tables, lengths, cfg, pat,
                                 use_kernel=use_kernel)
        new_states.append(ns)
    h = rmsnorm(params["final_norm"], h)
    logits = unembed(params["lm_head"], h[:, 0], cfg.logits_softcap)
    return logits, new_states
