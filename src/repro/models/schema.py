"""Parameter schema: define each tensor once — shape, logical dims, init.

Every model parameter is declared as a :class:`ParamDef`; the same
declaration yields (a) the initialised array, (b) the logical-dim annotation
consumed by ``distributed.sharding`` (which intersects it with the planner's
:class:`repro.core.planner.Plan`), and (c) the ShapeDtypeStruct used by the
dry-run.  Keeping one source of truth prevents shape/spec drift.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def normal(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def zeros() -> Initializer:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)
    return init


def ones() -> Initializer:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)
    return init


def uniform_range(lo: float, hi: float) -> Initializer:
    def init(key, shape, dtype):
        u = jax.random.uniform(key, shape, jnp.float32, lo, hi)
        return u.astype(dtype)
    return init


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter tensor: shape + logical dims + initializer."""

    shape: tuple[int, ...]
    dims: tuple[Optional[str], ...]     # logical dim name per axis (or None)
    init: Initializer = normal()
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


Schema = dict  # nested dict[str, ParamDef | Schema]


def init_params(schema: Schema, key: jax.Array) -> dict:
    """Instantiate every ParamDef with a derived PRNG key."""
    flat: list[tuple[tuple[str, ...], ParamDef]] = []

    def walk(node, path):
        if isinstance(node, ParamDef):
            flat.append((path, node))
        else:
            for k, v in sorted(node.items()):
                walk(v, path + (k,))

    walk(schema, ())
    keys = jax.random.split(key, max(len(flat), 1))
    out: dict = {}
    for (path, pd), k in zip(flat, keys):
        cur = out
        for p in path[:-1]:
            cur = cur.setdefault(p, {})
        cur[path[-1]] = pd.init(k, pd.shape, pd.dtype)
    return out


def param_dims(schema: Schema) -> dict:
    """Same tree, values = logical-dim tuples (for the sharding layer)."""
    if isinstance(schema, ParamDef):
        return schema.dims
    return {k: param_dims(v) for k, v in schema.items()}


def param_shapes(schema: Schema) -> dict:
    """Same tree, values = ShapeDtypeStruct (for dry-run, no allocation)."""
    if isinstance(schema, ParamDef):
        return jax.ShapeDtypeStruct(schema.shape, schema.dtype)
    return {k: param_shapes(v) for k, v in schema.items()}


def n_params(schema: Schema) -> int:
    if isinstance(schema, ParamDef):
        n = 1
        for s in schema.shape:
            n *= s
        return n
    return sum(n_params(v) for v in schema.values())


def stacked(pd: ParamDef, n: int, dim: str = "layers") -> ParamDef:
    """Add a leading layer-stack axis (for lax.scan over layers)."""
    return dataclasses.replace(pd, shape=(n,) + pd.shape,
                               dims=(dim,) + pd.dims)


def map_schema(fn: Callable[[ParamDef], ParamDef], schema: Schema) -> Schema:
    if isinstance(schema, ParamDef):
        return fn(schema)
    return {k: map_schema(fn, v) for k, v in schema.items()}
