"""Shared neural layers (pure JAX, functional params)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .schema import ParamDef, Schema, normal, ones, zeros


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_schema(d: int, dtype) -> Schema:
    return {"scale": ParamDef((d,), ("d_model",), ones(), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = jnp.arange(half, dtype=jnp.float32)
    inv = theta ** (-freqs / half)
    ang = positions[..., None, None].astype(jnp.float32) * inv  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None,
               ff_dim: str = "d_ff") -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.pdtype
    s = normal(0.02)
    return {
        "wi": ParamDef((d, f), ("d_model", ff_dim), s, dt),
        "wg": ParamDef((d, f), ("d_model", ff_dim), s, dt),
        "wo": ParamDef((f, d), (ff_dim, "d_model"), s, dt),
    }


def mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_schema(cfg: ModelConfig) -> Schema:
    return {"table": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "d_model"),
                              normal(1.0), cfg.pdtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_schema(cfg: ModelConfig) -> Schema:
    return {"w": ParamDef((cfg.d_model, cfg.vocab), ("d_model", "vocab"),
                          normal(0.02), cfg.pdtype)}


def unembed(params, x, softcap: float = 0.0):
    logits = jnp.einsum("...d,dv->...v", x, params["w"]).astype(jnp.float32)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def xent_loss(logits: jax.Array, labels: jax.Array,
              mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy.  logits (..., V) fp32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
