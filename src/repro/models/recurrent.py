"""Recurrent sequence mixers: RG-LRU (RecurrentGemma) and RWKV6 (Finch).

Both expose a full-sequence form (train/prefill; associative-scan or
time-scan) and a single-step form carrying explicit state (decode).  The
Pallas kernels in ``repro.kernels`` implement the chunked TPU versions of
the same math; these jnp forms are the oracles and the dry-run path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .schema import ParamDef, Schema, normal, uniform_range, zeros

# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# ---------------------------------------------------------------------------

_LRU_C = 8.0


def rglru_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    n = cfg.lru_width or d
    dt = cfg.pdtype
    s = normal(0.02)
    return {
        "wx": ParamDef((d, n), ("d_model", "lru"), s, dt),       # rec branch
        "wg": ParamDef((d, n), ("d_model", "lru"), s, dt),       # gate branch
        "conv": ParamDef((cfg.conv_width, n), (None, "lru"), s, dt),
        "gates": ParamDef((n, 2 * n), ("lru", "lru_gates"), s, dt),
        "lam": ParamDef((n,), ("lru",), uniform_range(2.0, 4.0), jnp.float32),
        "wo": ParamDef((n, d), ("lru", "d_model"), s, dt),
    }


class LRUState(NamedTuple):
    h: jax.Array          # (B, N) fp32 recurrence state
    conv: jax.Array       # (B, W-1, N) conv tail


def init_lru_state(cfg: ModelConfig, batch: int) -> LRUState:
    n = cfg.lru_width or cfg.d_model
    return LRUState(h=jnp.zeros((batch, n), jnp.float32),
                    conv=jnp.zeros((batch, cfg.conv_width - 1, n), cfg.cdtype))


def _lru_coeffs(params, xb):
    """Gate computations shared by scan and step forms.  xb: (..., N)."""
    gates = jnp.einsum("...n,nm->...m", xb, params["gates"])
    r, i = jnp.split(jax.nn.sigmoid(gates.astype(jnp.float32)), 2, axis=-1)
    log_a = -_LRU_C * r * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1-a^2) normaliser keeps the state scale input-independent
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xb.astype(jnp.float32))
    return a, b


def _causal_conv(params, xb, tail=None):
    """Depthwise causal temporal conv.  xb: (B,S,N); tail: (B,W-1,N)."""
    w = params["conv"].astype(xb.dtype)                 # (W, N)
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xb.shape[0], W - 1, xb.shape[2]), xb.dtype)
    xp = jnp.concatenate([tail, xb], axis=1)            # (B, S+W-1, N)
    out = sum(xp[:, i:i + xb.shape[1]] * w[i] for i in range(W))
    return out, xp[:, -(W - 1):]


def rglru_block(params, x, cfg: ModelConfig, *, use_kernel: bool = False):
    """Full-sequence Griffin recurrent block.  x: (B,S,D) → (B,S,D), and the
    final :class:`LRUState` so prefill can hand off to decode."""
    xb = jnp.einsum("bsd,dn->bsn", x, params["wx"])
    g = jnp.einsum("bsd,dn->bsn", x, params["wg"])
    xb, tail = _causal_conv(params, xb)
    a, b = _lru_coeffs(params, xb)
    if use_kernel:
        from repro.kernels import rglru as _k
        h = _k.lru_scan(a, b)
    else:
        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    y = h.astype(x.dtype) * jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsn,nd->bsd", y, params["wo"])
    return out, LRUState(h=h[:, -1], conv=tail)


def rglru_step(params, x, state: LRUState, cfg: ModelConfig):
    """One-token decode.  x: (B,1,D) → (B,1,D), new state."""
    xb = jnp.einsum("bsd,dn->bsn", x, params["wx"])
    g = jnp.einsum("bsd,dn->bsn", x, params["wg"])
    xb, tail = _causal_conv(params, xb, state.conv)
    a, b = _lru_coeffs(params, xb[:, 0])
    h = a * state.h + b
    y = h[:, None].astype(x.dtype) * \
        jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsn,nd->bsd", y, params["wo"])
    return out, LRUState(h=h, conv=tail)


# ---------------------------------------------------------------------------
# RWKV6 time mix + channel mix
# ---------------------------------------------------------------------------

def _rwkv_heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.head_dim or 64
    return cfg.d_model // hd, hd


def rwkv6_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    H, hd = _rwkv_heads(cfg)
    dt = cfg.pdtype
    s = normal(0.02)
    lora = 64
    return {
        "mix": ParamDef((5, d), (None, "d_model"), normal(0.5), dt),
        "wr": ParamDef((d, d), ("d_model", "heads_flat"), s, dt),
        "wk": ParamDef((d, d), ("d_model", "heads_flat"), s, dt),
        "wv": ParamDef((d, d), ("d_model", "heads_flat"), s, dt),
        "wg": ParamDef((d, d), ("d_model", "heads_flat"), s, dt),
        "w0": ParamDef((d,), ("d_model",), uniform_range(-7.0, -5.0), jnp.float32),
        "w_lora_a": ParamDef((d, lora), ("d_model", None), s, dt),
        "w_lora_b": ParamDef((lora, d), (None, "d_model"), s, dt),
        "u": ParamDef((H, hd), ("heads", "head_dim"), normal(0.3), jnp.float32),
        "wo": ParamDef((d, d), ("heads_flat", "d_model"), s, dt),
        "ln_x": ParamDef((d,), ("d_model",), zeros(), jnp.float32),
    }


class RWKVState(NamedTuple):
    S: jax.Array          # (B, H, hd, hd) fp32 wkv state
    shift: jax.Array      # (B, D) previous post-ln1 input (time mix)
    cshift: jax.Array     # (B, D) previous post-ln2 input (channel mix)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    H, hd = _rwkv_heads(cfg)
    z = jnp.zeros((batch, cfg.d_model), cfg.cdtype)
    return RWKVState(S=jnp.zeros((batch, H, hd, hd), jnp.float32),
                     shift=z, cshift=z)


def _rwkv_proj(params, x, xprev):
    """Token-shift mixes + projections.  x: (B,S,D), xprev shifted x."""
    mix = params["mix"].astype(x.dtype)                  # (5, D)
    def mixed(i):
        return x + (xprev - x) * mix[i]
    r = jnp.einsum("bsd,de->bse", mixed(0), params["wr"])
    k = jnp.einsum("bsd,de->bse", mixed(1), params["wk"])
    v = jnp.einsum("bsd,de->bse", mixed(2), params["wv"])
    g = jnp.einsum("bsd,de->bse", mixed(3), params["wg"])
    wx = mixed(4)
    lora = jnp.einsum("bsd,dl->bsl", wx, params["w_lora_a"])
    lora = jnp.einsum("bsl,ld->bsd", jnp.tanh(lora.astype(jnp.float32))
                      .astype(wx.dtype), params["w_lora_b"])
    logw = params["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                          # (B,S,D) decay in (0,1)
    return r, k, v, g, w


def _group_norm(params, y, H):
    """Per-head groupnorm on (B,S,H,hd) flattened output."""
    B, S, D = y.shape
    yh = y.reshape(B, S, H, D // H).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    yh = yh.reshape(B, S, D)
    return (yh * (1.0 + params["ln_x"])).astype(y.dtype)


def rwkv6_time_mix(params, x, cfg: ModelConfig, *, use_kernel: bool = False):
    """Full-sequence WKV.  x: (B,S,D) → (B,S,D)."""
    H, hd = _rwkv_heads(cfg)
    B, S, D = x.shape
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv_proj(params, x, xprev)
    rh = r.reshape(B, S, H, hd).astype(jnp.float32)
    kh = k.reshape(B, S, H, hd).astype(jnp.float32)
    vh = v.reshape(B, S, H, hd).astype(jnp.float32)
    wh = w.reshape(B, S, H, hd)
    u = params["u"]

    if use_kernel:
        from repro.kernels import rwkv6 as _k
        yh, S_final = _k.wkv(rh, kh, vh, wh, u)
    else:
        def step(S_, inp):
            r_, k_, v_, w_ = inp            # (B,H,hd)
            kv = k_[..., :, None] * v_[..., None, :]        # (B,H,hd,hd)
            out = jnp.einsum("bhk,bhkv->bhv", r_,
                             S_ + u[None, :, :, None] * kv)
            S_ = w_[..., :, None] * S_ + kv
            return S_, out
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        S_final, y = jax.lax.scan(step, S0,
                                  (rh.swapaxes(0, 1), kh.swapaxes(0, 1),
                                   vh.swapaxes(0, 1), wh.swapaxes(0, 1)))
        yh = y.swapaxes(0, 1)               # (B,S,H,hd)

    y = yh.reshape(B, S, D).astype(x.dtype)
    y = _group_norm(params, y, H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    return out, RWKVState(S=S_final, shift=x[:, -1].astype(x.dtype),
                          cshift=jnp.zeros_like(x[:, -1]))


def rwkv6_time_mix_step(params, x, state: RWKVState, cfg: ModelConfig):
    """One-token decode.  x: (B,1,D)."""
    H, hd = _rwkv_heads(cfg)
    B, _, D = x.shape
    xprev = state.shift[:, None].astype(x.dtype)
    r, k, v, g, w = _rwkv_proj(params, x, xprev)
    r_ = r.reshape(B, H, hd).astype(jnp.float32)
    k_ = k.reshape(B, H, hd).astype(jnp.float32)
    v_ = v.reshape(B, H, hd).astype(jnp.float32)
    w_ = w.reshape(B, H, hd)
    u = params["u"]
    kv = k_[..., :, None] * v_[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r_, state.S + u[None, :, :, None] * kv)
    S = w_[..., :, None] * state.S + kv
    y = out.reshape(B, 1, D).astype(x.dtype)
    y = _group_norm(params, y, H)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    y = jnp.einsum("bse,ed->bsd", y, params["wo"])
    return y, RWKVState(S=S, shift=x[:, 0].astype(state.shift.dtype),
                        cshift=state.cshift)


def rwkv6_channel_mix_schema(cfg: ModelConfig) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype
    s = normal(0.02)
    return {
        "mix": ParamDef((2, d), (None, "d_model"), normal(0.5), dt),
        "wk": ParamDef((d, f), ("d_model", "d_ff"), s, dt),
        "wv": ParamDef((f, d), ("d_ff", "d_model"), s, dt),
        "wr": ParamDef((d, d), ("d_model", None), s, dt),
    }


def rwkv6_channel_mix(params, x, xprev):
    mix = params["mix"].astype(x.dtype)
    xk = x + (xprev - x) * mix[0]
    xr = x + (xprev - x) * mix[1]
    k = jnp.einsum("bsd,df->bsf", xk, params["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, params["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * kv
