"""Model zoo public API: step functions, input specs, bubble trees.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of a given workload
shape — the currency of the multi-pod dry-run.

``bubble_tree`` emits the planner-side bubble tree for an (arch × shape)
cell: the application-structure description the bubble scheduler consumes
to derive the sharding plan.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bubble import Bubble, bubble
from repro.core.planner import Dim

from . import lm
from .config import ModelConfig
from .schema import init_params, param_dims, param_shapes


# ---------------------------------------------------------------------------
# workload shapes (the assigned input-shape set)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k":    dict(kind="train",   seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32_768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524_288, batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Whether an (arch × shape) cell is lowered (DESIGN §Arch-applicability)."""
    info = SHAPES[shape]
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k dense KV cache is skipped"
    if info["kind"] == "decode" and cfg.enc_layers and shape == "long_500k":
        return False, "enc-dec decoder is full-attention"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    specs = {}
    if cfg.enc_layers or cfg.frontend == "audio":
        # enc-dec: source = stub frames, target = tokens
        specs["frontend_embeds"] = _sds((batch, seq, cfg.d_model), "bfloat16")
        specs["tokens"] = _sds((batch, seq), "int32")
        specs["labels"] = _sds((batch, seq), "int32")
    elif cfg.frontend == "vision":
        P = min(cfg.frontend_tokens, seq - 16)
        specs["frontend_embeds"] = _sds((batch, P, cfg.d_model), "bfloat16")
        specs["tokens"] = _sds((batch, seq - P), "int32")
        specs["labels"] = _sds((batch, seq - P), "int32")
    else:
        specs["tokens"] = _sds((batch, seq), "int32")
        specs["labels"] = _sds((batch, seq), "int32")
    return specs


def prefill_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    specs = train_specs(cfg, batch, seq)
    specs.pop("labels", None)
    return specs


def decode_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """One decode step against a cache of logical length ``seq``."""
    c = lm._dec_cfg(cfg) if cfg.enc_layers else cfg
    states = jax.eval_shape(
        lambda: lm.init_state(c, batch, seq, start_pos=seq))
    specs = {"token": _sds((batch, 1), "int32"), "states": states}
    if cfg.enc_layers:
        specs["enc"] = _sds((batch, min(seq, 4096), cfg.d_model), "bfloat16")
    return specs


def params_specs(cfg: ModelConfig):
    return param_shapes(lm.lm_schema(cfg))


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    info = SHAPES[shape]
    fn = {"train": train_specs, "prefill": prefill_specs,
          "decode": decode_specs}[info["kind"]]
    return fn(cfg, info["batch"], info["seq"])


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, use_kernel: bool = False,
                 remat: bool = False):
    def loss(params, batch):
        return lm.loss_fn(params, batch, cfg, use_kernel=use_kernel,
                          remat=remat)
    return loss


def make_prefill_fn(cfg: ModelConfig, cache_len: int,
                    use_kernel: bool = False):
    if cfg.enc_layers:
        def pf(params, batch):
            return lm.encdec_prefill(params, batch, cfg, cache_len)
        return pf
    def pf(params, batch):
        return lm.prefill(params, batch, cfg, cache_len,
                          use_kernel=use_kernel)
    return pf


def make_decode_fn(cfg: ModelConfig):
    if cfg.enc_layers:
        def step(params, token, states, enc):
            return lm.encdec_decode_step(params, token, states, enc, cfg)
        return step
    def step(params, token, states):
        return lm.decode_step(params, token, states, cfg)
    return step


def make_paged_decode_fn(cfg: ModelConfig, use_kernel: bool = False):
    """Decode step over paged KV: ``(params, token, states, tables,
    lengths) -> (logits, states)``.  See ``models.paged``."""
    from . import paged

    def step(params, token, states, tables, lengths):
        return paged.decode_step(params, token, states, tables, lengths,
                                 cfg, use_kernel=use_kernel)
    return step


def batch_axis_spec(init_fn):
    """Infer, per state leaf, which axis carries the batch.

    ``init_fn(batch)`` builds (or ``eval_shape``s) a state pytree for a
    given batch size.  Comparing the leaf shapes at two batch sizes pins
    the batch axis exactly: the one axis whose extent differs.  Returns a
    matching pytree of ints — the batch axis, or ``-1`` for batch-free
    leaves (shared pools, scalars), which splice/extract must pass
    through untouched.

    This replaces the ``ndim >= 2`` heuristic the serving backends used
    to guess batch leaves with: that guess silently skipped genuine 1-D
    per-slot leaves (a ``(B,)`` position or flag vector) and corrupted
    nothing only as long as no model had one.  An explicit spec fails
    loudly instead: a leaf whose shape varies on more than one axis is a
    structural error, not a leaf to skip.
    """
    a = jax.eval_shape(lambda: init_fn(2))
    b = jax.eval_shape(lambda: init_fn(3))

    def one(x, y):
        assert len(x.shape) == len(y.shape), (x.shape, y.shape)
        diff = [i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                if p != q]
        if not diff:
            return -1
        if len(diff) > 1:
            raise ValueError(
                f"state leaf varies on {len(diff)} axes with batch "
                f"({x.shape} vs {y.shape}): not a batch-sliceable leaf")
        return diff[0]

    return jax.tree.map(one, a, b)


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(lm.lm_schema(cfg), key)


def dims(cfg: ModelConfig):
    return param_dims(lm.lm_schema(cfg))


# ---------------------------------------------------------------------------
# bubble tree for the placement planner
# ---------------------------------------------------------------------------

def bubble_tree(cfg: ModelConfig, shape: str) -> Bubble:
    """The application-structure description for one (arch × shape) cell.

    Nesting: train_step ⊃ {data bubble, layer bubble ⊃ {attn, ffn/moe,
    rec/rwkv sub-bubbles}, embed bubble}.  Parameter dims set
    ``min_level="model"`` so their collectives stay on the innermost
    (cheapest) axis — the affinity statement; the data bubble tolerates any
    level (batch gradients all-reduce across pods by design).
    """
    info = SHAPES[shape]
    root = bubble(name=f"{cfg.name}:{shape}")
    root.insert(bubble(Dim(name="batch", width=info["batch"], weight=1.0,
                           is_activation=True),
                       name="data"))

    layer = bubble(name="layer", burst_level="model")
    kinds = set(cfg.block_pattern)
    if "attn" in kinds or cfg.enc_layers:
        layer.insert(bubble(
            Dim(name="heads", width=max(cfg.n_heads, 1), weight=2.5),
            Dim(name="kv_heads", width=max(cfg.n_kv_heads, 1), weight=1.0),
            name="attn"))
    if "rec" in kinds:
        layer.insert(bubble(
            Dim(name="lru", width=cfg.lru_width or cfg.d_model, weight=2.5),
            name="rec"))
    if "rwkv" in kinds:
        layer.insert(bubble(
            Dim(name="heads_flat", width=cfg.d_model, weight=2.5),
            name="tmix"))
    if cfg.n_experts:
        layer.insert(bubble(
            Dim(name="experts", width=cfg.n_experts, weight=4.0),
            Dim(name="d_ff", width=cfg.d_ff, weight=2.0),
            name="moe"))
        # NOTE: a separate shared-expert bubble (d_ff_shared -> model) was
        # tried and REFUTED: TP partial-sum all-reduces of the shared FFN
        # outweigh its compute saving (EXPERIMENTS.md §Perf, deepseek iter 2)

    else:
        layer.insert(bubble(
            Dim(name="d_ff", width=cfg.d_ff, weight=2.0),
            name="ffn"))
    root.insert(layer)
    root.insert(bubble(
        Dim(name="vocab", width=cfg.vocab, weight=1.5, min_level="model"),
        name="embed"))
    return root
