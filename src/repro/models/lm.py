"""Causal LM assembly: pattern-stacked blocks under ``lax.scan``.

A model is a repeating *block pattern* (dense: ``("attn",)``;
RecurrentGemma: ``("rec","rec","attn")``; RWKV6: ``("rwkv",)``), each block
being pre-norm residual sublayers.  Parameters for each pattern position are
stacked over the repeat count and scanned, so the lowered HLO is one block
per pattern position regardless of depth — critical for dry-run compile
times on 512 devices and the idiom XLA pipelines best.

Public entry points (pure functions of (params, batch)):

* ``loss_fn``     — next-token loss (training forward)
* ``prefill``     — full-sequence forward returning last logits + decode
                    state with genuinely populated caches
* ``decode_step`` — one token in, one token out, state carried

Encoder-decoder (seamless-m4t) and modality frontends (llava/seamless) are
layered on the same machinery at the bottom of the file.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .attention import (KVCache, attn_schema, attention, decode_attention,
                        init_cache, prefill_cache)
from .config import ModelConfig
from .layers import (embed, embed_schema, mlp, mlp_schema, rmsnorm,
                     rmsnorm_schema, unembed, unembed_schema, xent_loss)
from .moe import moe_ffn, moe_schema
from .recurrent import (LRUState, RWKVState, init_lru_state, init_rwkv_state,
                        rglru_block, rglru_schema, rglru_step,
                        rwkv6_channel_mix, rwkv6_channel_mix_schema,
                        rwkv6_schema, rwkv6_time_mix, rwkv6_time_mix_step)
from .schema import (ParamDef, Schema, init_params, map_schema, n_params,
                     normal, param_dims, param_shapes, stacked)

# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------


def _ffn_schema(cfg: ModelConfig) -> Schema:
    return moe_schema(cfg) if cfg.n_experts else mlp_schema(cfg)


def block_schema(cfg: ModelConfig, kind: str) -> Schema:
    d = cfg.d_model
    dt = cfg.pdtype
    if kind == "attn":
        return {"ln1": rmsnorm_schema(d, dt), "attn": attn_schema(cfg),
                "ln2": rmsnorm_schema(d, dt), "ffn": _ffn_schema(cfg)}
    if kind == "rec":
        return {"ln1": rmsnorm_schema(d, dt), "rec": rglru_schema(cfg),
                "ln2": rmsnorm_schema(d, dt), "ffn": mlp_schema(cfg)}
    if kind == "rwkv":
        return {"ln1": rmsnorm_schema(d, dt), "tmix": rwkv6_schema(cfg),
                "ln2": rmsnorm_schema(d, dt),
                "cmix": rwkv6_channel_mix_schema(cfg)}
    if kind == "xattn":      # decoder block with cross attention
        return {"ln1": rmsnorm_schema(d, dt), "attn": attn_schema(cfg),
                "lnx": rmsnorm_schema(d, dt), "xattn": attn_schema(cfg),
                "ln2": rmsnorm_schema(d, dt), "ffn": _ffn_schema(cfg)}
    raise ValueError(kind)


def _stages(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """Split n_layers into (pattern, repeats) stages; remainder layers form
    a trailing stage with a truncated pattern."""
    pat = cfg.block_pattern
    groups, rem = divmod(cfg.n_layers, len(pat))
    out: list[tuple[tuple[str, ...], int]] = []
    if groups:
        out.append((pat, groups))
    if rem:
        out.append((pat[:rem], 1))
    return out


def lm_schema(cfg: ModelConfig) -> Schema:
    if cfg.enc_layers:
        return encdec_schema(cfg)
    sch: Schema = {"embed": embed_schema(cfg)}
    for si, (pat, reps) in enumerate(_stages(cfg)):
        stage: Schema = {}
        for pi, kind in enumerate(pat):
            stage[f"b{pi}_{kind}"] = map_schema(
                lambda pd: stacked(pd, reps), block_schema(cfg, kind))
        sch[f"stage{si}"] = stage
    sch["final_norm"] = rmsnorm_schema(cfg.d_model, cfg.pdtype)
    sch["lm_head"] = unembed_schema(cfg)
    if cfg.frontend:
        sch["frontend"] = {"proj": ParamDef(
            (cfg.d_model, cfg.d_model), (None, "d_model"), normal(0.02),
            cfg.pdtype)}
    return sch


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    c = cfg
    if active_only and cfg.n_experts:
        c = dataclasses.replace(cfg, n_experts=max(cfg.top_k, 1))
    return n_params(lm_schema(c))


# ---------------------------------------------------------------------------
# single-block forward / step
# ---------------------------------------------------------------------------

def _ffn_apply(params, y, cfg: ModelConfig):
    if cfg.n_experts:
        return moe_ffn(params, y, cfg)
    return mlp(params, y), jnp.zeros((), jnp.float32)


def block_fwd(params, x, positions, cfg: ModelConfig, kind: str, *,
              enc: Optional[jax.Array] = None, cache_len: int = 0,
              use_kernel: bool = False):
    """Full-sequence block → (x, aux_loss, decode_state_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    state = None
    if kind in ("attn", "xattn"):
        h, (k, v) = attention(params["attn"], rmsnorm(params["ln1"], x),
                              positions, cfg, use_kernel=use_kernel,
                              return_kv=True)
        if cache_len:
            state = prefill_cache(k, v, cfg, cache_len)
        x = x + h
        if kind == "xattn":
            assert enc is not None
            h = attention(params["xattn"], rmsnorm(params["lnx"], x),
                          positions, cfg, kv=(enc, None))
            x = x + h
        h, aux = _ffn_apply(params["ffn"], rmsnorm(params["ln2"], x), cfg)
        return x + h, aux, state
    if kind == "rec":
        h, st = rglru_block(params["rec"], rmsnorm(params["ln1"], x), cfg,
                            use_kernel=use_kernel)
        state = st if cache_len else None
        x = x + h
        h = mlp(params["ffn"], rmsnorm(params["ln2"], x))
        return x + h, aux, state
    if kind == "rwkv":
        h, st = rwkv6_time_mix(params["tmix"], rmsnorm(params["ln1"], x),
                               cfg, use_kernel=use_kernel)
        x = x + h
        y = rmsnorm(params["ln2"], x)
        yprev = jnp.pad(y, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if cache_len:
            state = RWKVState(S=st.S, shift=st.shift, cshift=y[:, -1])
        h = rwkv6_channel_mix(params["cmix"], y, yprev)
        return x + h, aux, state
    raise ValueError(kind)


def block_step(params, x, st, cfg: ModelConfig, kind: str, *,
               enc: Optional[jax.Array] = None):
    """One-token block → (x, new_state)."""
    if kind in ("attn", "xattn"):
        h, new = decode_attention(params["attn"], rmsnorm(params["ln1"], x),
                                  st, cfg)
        x = x + h
        if kind == "xattn":
            assert enc is not None
            pos = (new.pos - 1)[:, None]
            h = attention(params["xattn"], rmsnorm(params["lnx"], x), pos,
                          cfg, kv=(enc, None))
            x = x + h
        h, _ = _ffn_apply(params["ffn"], rmsnorm(params["ln2"], x), cfg)
        return x + h, new
    if kind == "rec":
        h, new = rglru_step(params["rec"], rmsnorm(params["ln1"], x), st, cfg)
        x = x + h
        h = mlp(params["ffn"], rmsnorm(params["ln2"], x))
        return x + h, new
    if kind == "rwkv":
        h, new = rwkv6_time_mix_step(params["tmix"],
                                     rmsnorm(params["ln1"], x), st, cfg)
        x = x + h
        y = rmsnorm(params["ln2"], x)
        yprev = st.cshift[:, None].astype(y.dtype)
        h = rwkv6_channel_mix(params["cmix"], y, yprev)
        new = RWKVState(S=new.S, shift=new.shift, cshift=y[:, 0])
        return x + h, new
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# decode-state construction
# ---------------------------------------------------------------------------

def _cache_len(cfg: ModelConfig, target: int) -> int:
    if cfg.window is not None:
        return min(cfg.window, target)
    return target


def init_state(cfg: ModelConfig, batch: int, cache_len: int,
               start_pos: int = 0):
    """Fresh (empty) decode state for every stage/pattern position.

    ``start_pos`` pre-advances the positions (used by dry-run decode shapes:
    a cache that is semantically full at position ``start_pos``)."""
    def stk(make, reps):
        one = make()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one)
    C = _cache_len(cfg, cache_len)
    states = []
    for pat, reps in _stages(cfg):
        st = []
        for kind in pat:
            if kind in ("attn", "xattn"):
                def mk():
                    c = init_cache(cfg, batch, C)
                    return KVCache(c.k, c.v,
                                   jnp.full((batch,), start_pos, jnp.int32))
                st.append(stk(mk, reps))
            elif kind == "rec":
                st.append(stk(lambda: init_lru_state(cfg, batch), reps))
            else:
                st.append(stk(lambda: init_rwkv_state(cfg, batch), reps))
        states.append(tuple(st))
    return states


# ---------------------------------------------------------------------------
# stacked forward (scan over repeats)
# ---------------------------------------------------------------------------

def _scan_stage(params_stage, x, positions, cfg, pat, *, enc=None,
                cache_len=0, use_kernel=False, remat=False):
    """Full-seq forward through one stage.  Returns (x, aux, states)."""
    def body(carry, layer_params):
        x, aux = carry
        if cfg.sp_axis is not None:
            # sequence parallelism: the residual carry lives sharded over
            # the model axis between blocks (activation memory / axis size;
            # XLA turns the TP all-reduces into reduce-scatter/all-gather)
            from jax.sharding import PartitionSpec as _P
            b = tuple(cfg.batch_axes) or None
            x = jax.lax.with_sharding_constraint(
                x, _P(b, cfg.sp_axis, None))
        sts = []
        for pi, kind in enumerate(pat):
            x, a, st = block_fwd(layer_params[f"b{pi}_{kind}"], x, positions,
                                 cfg, kind, enc=enc, cache_len=cache_len,
                                 use_kernel=use_kernel)
            aux = aux + a
            sts.append(st)
        return (x, aux), (tuple(sts) if cache_len else None)

    if remat:
        # store only the per-layer carry; recompute block internals in the
        # backward pass (activation-checkpointing at block granularity)
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    reps = jax.tree.leaves(params_stage)[0].shape[0]
    (x, aux), states = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params_stage,
        unroll=reps if cfg.scan_unroll else 1)
    return x, aux, states


def _scan_stage_step(params_stage, x, states, cfg, pat, *, enc=None):
    """One-token step through one stage; states = tuple per pattern pos."""
    def body(x, inp):
        layer_params, layer_states = inp
        new_states = []
        for pi, kind in enumerate(pat):
            x, ns = block_step(layer_params[f"b{pi}_{kind}"], x,
                               layer_states[pi], cfg, kind, enc=enc)
            new_states.append(ns)
        return x, tuple(new_states)

    x, new = jax.lax.scan(body, x, (params_stage, states))
    return x, new


def backbone(params, x, positions, cfg: ModelConfig, *, enc=None,
             cache_len=0, use_kernel=False, remat=False):
    aux = jnp.zeros((), jnp.float32)
    all_states = []
    for si, (pat, _) in enumerate(_stages(cfg)):
        x, a, st = _scan_stage(params[f"stage{si}"], x, positions, cfg, pat,
                               enc=enc, cache_len=cache_len,
                               use_kernel=use_kernel, remat=remat)
        aux = aux + a
        all_states.append(st)
    return rmsnorm(params["final_norm"], x), aux, all_states


def backbone_step(params, x, states, cfg: ModelConfig, *, enc=None):
    new_states = []
    for si, (pat, _) in enumerate(_stages(cfg)):
        x, ns = _scan_stage_step(params[f"stage{si}"], x, states[si], cfg,
                                 pat, enc=enc)
        new_states.append(ns)
    return rmsnorm(params["final_norm"], x), new_states


# ---------------------------------------------------------------------------
# top-level entry points
# ---------------------------------------------------------------------------

def _inputs_to_h(params, batch: dict, cfg: ModelConfig):
    """tokens (+ optional frontend embeddings) → (B,S,D) activations."""
    h = embed(params["embed"], batch["tokens"]).astype(cfg.cdtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    if cfg.frontend and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(cfg.cdtype)
        fe = jnp.einsum("bpd,de->bpe", fe, params["frontend"]["proj"])
        h = jnp.concatenate([fe, h], axis=1)
    return h


def loss_fn(params, batch: dict, cfg: ModelConfig, *,
            use_kernel: bool = False, remat: bool = False) -> jax.Array:
    """Training loss.  batch: tokens (B,S), labels (B,S), optional
    frontend_embeds (B,P,D)."""
    if cfg.enc_layers:
        return _encdec_loss(params, batch, cfg, use_kernel=use_kernel,
                            remat=remat)
    h = _inputs_to_h(params, batch, cfg)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    h, aux, _ = backbone(params, h, positions, cfg, use_kernel=use_kernel,
                         remat=remat)
    P = h.shape[1] - batch["tokens"].shape[1]
    if P > 0:
        h = h[:, P:]
    logits = unembed(params["lm_head"], h, cfg.logits_softcap)
    return xent_loss(logits, batch["labels"]) + 0.01 * aux


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int, *,
            use_kernel: bool = False):
    """Returns (last-token logits (B,V), decode states with populated
    caches/recurrent states)."""
    if cfg.enc_layers:
        raise ValueError("use encdec_prefill for encoder-decoder models")
    h = _inputs_to_h(params, batch, cfg)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    C = _cache_len(cfg, cache_len)
    h, _, states = backbone(params, h, positions, cfg, cache_len=C,
                            use_kernel=use_kernel)
    logits = unembed(params["lm_head"], h[:, -1], cfg.logits_softcap)
    return logits, states


def decode_step(params, token: jax.Array, states, cfg: ModelConfig, *,
                enc: Optional[jax.Array] = None):
    """token: (B,1) int32 → (logits (B,V), new states)."""
    h = embed(params["embed"], token).astype(cfg.cdtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    h, new_states = backbone_step(params, h, states, cfg, enc=enc)
    logits = unembed(params["lm_head"], h[:, 0], cfg.logits_softcap)
    return logits, new_states


# ---------------------------------------------------------------------------
# encoder-decoder (seamless-m4t backbone)
# ---------------------------------------------------------------------------

def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=cfg.enc_layers, enc_layers=0,
                               frontend=None, window=None,
                               block_pattern=("attn",))


def _dec_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, enc_layers=0, frontend=None,
                               block_pattern=("xattn",))


def encdec_schema(cfg: ModelConfig) -> Schema:
    ec = _enc_cfg(cfg)
    sch: Schema = {"encoder": {}}
    for si, (pat, reps) in enumerate(_stages(ec)):
        stage: Schema = {}
        for pi, kind in enumerate(pat):
            stage[f"b{pi}_{kind}"] = map_schema(
                lambda pd: stacked(pd, reps), block_schema(ec, kind))
        sch["encoder"][f"stage{si}"] = stage
    sch["encoder"]["final_norm"] = rmsnorm_schema(cfg.d_model, cfg.pdtype)
    sch.update(lm_schema(_dec_cfg(cfg)))
    return sch


def encode(params, batch, cfg: ModelConfig, *, use_kernel=False):
    """Bidirectional encoder over stub frame embeddings (B,T,D)."""
    ec = _enc_cfg(cfg)
    h = batch["frontend_embeds"].astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])

    for si, (pat, _) in enumerate(_stages(ec)):
        def body(x, layer_params):
            p = layer_params["b0_attn"]
            y = attention(p["attn"], rmsnorm(p["ln1"], x), positions, ec,
                          causal=False)
            x = x + y
            y = mlp(p["ffn"], rmsnorm(p["ln2"], x))
            return x + y, None
        reps = jax.tree.leaves(params["encoder"][f"stage{si}"])[0].shape[0]
        h, _ = jax.lax.scan(body, h, params["encoder"][f"stage{si}"],
                            unroll=reps if ec.scan_unroll else 1)
    return rmsnorm(params["encoder"]["final_norm"], h)


def _encdec_loss(params, batch, cfg: ModelConfig, *, use_kernel=False,
                 remat=False):
    enc = encode(params, batch, cfg, use_kernel=use_kernel)
    dc = _dec_cfg(cfg)
    h = embed(params["embed"], batch["tokens"]).astype(cfg.cdtype)
    h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]), h.shape[:2])
    h, aux, _ = backbone(params, h, positions, dc, enc=enc,
                         use_kernel=use_kernel, remat=remat)
    logits = unembed(params["lm_head"], h, cfg.logits_softcap)
    return xent_loss(logits, batch["labels"]) + 0.01 * aux


def encdec_prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Encode source; return (enc, fresh decoder states)."""
    enc = encode(params, batch, cfg)
    states = init_state(_dec_cfg(cfg), enc.shape[0], cache_len)
    return enc, states


def encdec_decode_step(params, token, states, enc, cfg: ModelConfig):
    return decode_step(params, token, states, _dec_cfg(cfg), enc=enc)
