"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Dispatch is gather/scatter (argsort of expert assignments → fixed-capacity
expert buffers), NOT the Mesh-TensorFlow one-hot einsum: the one-hot
dispatch tensor is O(T·E·C) and reaches tens of TB at the assigned shapes
(grok train_4k: T=65k per chip), while sort-based dispatch is O(T·K).
The expert buffers keep a static (E, C, D) shape so the expert matmuls are
ordinary einsums shardable over the experts axis (EP).  Overflowing tokens
beyond capacity are dropped (standard Switch behaviour); their gates are
zeroed so the combine stays correct.

Covers grok-1 (8e top-2) and DeepSeekMoE (2 shared + 64 routed top-6,
fine-grained d_ff).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mlp, mlp_schema
from .schema import ParamDef, Schema, normal


def moe_schema(cfg: ModelConfig) -> Schema:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.pdtype
    s = normal(0.02)
    sch: Schema = {
        "router": ParamDef((d, e), ("d_model", "experts"), s, dt),
        "wi": ParamDef((e, d, f), ("experts", "d_model", "d_ff"), s, dt),
        "wg": ParamDef((e, d, f), ("experts", "d_model", "d_ff"), s, dt),
        "wo": ParamDef((e, f, d), ("experts", "d_ff", "d_model"), s, dt),
    }
    if cfg.n_shared_experts:
        sch["shared"] = mlp_schema(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts,
                                   ff_dim="d_ff_shared")
    return sch


def _capacity(tokens: int, cfg: ModelConfig, factor: float = 1.25) -> int:
    """Per-row expert capacity.  Floor is top_k (a row can always place all
    its assignments somewhere), NOT a fixed 8 — at decode (S=1) a floor of 8
    inflates expert compute by E*8/K (measured 32-85x on grok/deepseek)."""
    cap = int(tokens * cfg.top_k / cfg.n_experts * factor)
    aligned = (cap + 7) // 8 * 8
    return max(cfg.top_k, aligned)


def moe_ffn(params, x, cfg: ModelConfig, *,
            capacity_factor: float = 1.5) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) → (B,S,D), aux load-balance loss (scalar fp32).

    Dispatch is ROW-LOCAL: every batch row dispatches into its own
    per-expert capacity slice, so the buffers keep a leading batch dim
    (B, E, C, D) and GSPMD shards the expert compute over BOTH the data
    axis (rows) and the experts axis (EP).  A flat (E, T·K/E, D) buffer has
    no batch dim, which replicates the expert matmuls across the data axis
    — measured 13-16x redundant compute per chip on the production mesh
    (EXPERIMENTS.md §Perf, iteration 1)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg, capacity_factor)     # per-row capacity

    logits = jnp.einsum("bsd,de->bse", x, params["router"]) \
        .astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style), global over the batch
    me = probs.mean((0, 1))                                # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0) / (B * S * K)
    aux = E * jnp.sum(me * ce)

    # ---- row-local, GATHER-only dispatch ---------------------------------
    # No scatters: GSPMD cannot partition a batched scatter and falls back
    # to full replication (measured: 72 GiB fp32 all-gathers of the global
    # dispatch buffer per layer on grok — EXPERIMENTS §Perf cell 2 iter 5).
    # Sort once, then express both dispatch and combine as gathers.
    SK = S * K
    e_flat = gate_idx.reshape(B, SK)
    order = jnp.argsort(e_flat, axis=1)                    # stable per row
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    tok_sorted = order // K                                # (B, SK)

    # expert segment boundaries in the sorted stream
    eids = jnp.arange(E)
    starts = jax.vmap(lambda es: jnp.searchsorted(es, eids, side="left"))(
        e_sorted)                                          # (B, E)
    ends = jax.vmap(lambda es: jnp.searchsorted(es, eids, side="right"))(
        e_sorted)                                          # (B, E)

    # dispatch: buf[b,e,c] = x[b, tok_sorted[b, starts[b,e]+c]] (if valid)
    idx = starts[:, :, None] + jnp.arange(C)[None, None]   # (B, E, C)
    valid = idx < ends[:, :, None]
    idx = jnp.minimum(idx, SK - 1).reshape(B, E * C)
    src_tok = jnp.take_along_axis(tok_sorted, idx, axis=1)  # (B, E*C)
    buf = jnp.take_along_axis(x, src_tok[..., None], axis=1)  # (B,E*C,D)
    buf = buf.reshape(B, E, C, D) * valid[..., None].astype(x.dtype)

    h = jnp.einsum("becd,edf->becf", buf, params["wi"])
    g = jnp.einsum("becd,edf->becf", buf, params["wg"])
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("becf,efd->becd", h, params["wo"])    # (B,E,C,D)
    out_flat = out.reshape(B, E * C, D)

    # ---- combine (gathers only) -------------------------------------------
    # invert the sort with a second argsort; slot of assignment j is
    # e*C + (rank within segment), dropped if rank >= C
    inv = jnp.argsort(order, axis=1)                       # (B, SK)
    pos_sorted = jnp.arange(SK)[None] - jnp.take_along_axis(
        starts, e_sorted, axis=1)                          # rank in segment
    pos = jnp.take_along_axis(pos_sorted, inv, axis=1)     # (B, SK) unsorted
    kept = pos < C
    rows = jnp.where(kept, e_flat * C + pos, 0)
    gathered = jnp.take_along_axis(out_flat, rows[..., None], axis=1)
    w = (gate_vals.reshape(B, SK) * kept).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(B, S, K, D).sum(axis=2)

    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x)
    return y, aux
