"""GQA attention: training/prefill (full sequence) and cached decode.

The full-sequence path optionally routes through the Pallas flash-attention
kernel (``repro.kernels``); the einsum reference is the default (and the
path used by the multi-pod dry-run — the kernel is TPU-targeted and
validated in interpret mode by the tests).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rope
from .schema import ParamDef, Schema, normal


def attn_schema(cfg: ModelConfig) -> Schema:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.pdtype
    s = normal(0.02)
    return {
        "wq": ParamDef((d, h, hd), ("d_model", "heads", "head_dim"), s, dt),
        "wk": ParamDef((d, k, hd), ("d_model", "kv_heads", "head_dim"), s, dt),
        "wv": ParamDef((d, k, hd), ("d_model", "kv_heads", "head_dim"), s, dt),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "d_model"), s, dt),
    }


class KVCache(NamedTuple):
    k: jax.Array          # (B, C, K, hd)
    v: jax.Array          # (B, C, K, hd)
    pos: jax.Array        # (B,) int32 — next write position (= tokens seen)


def init_cache(cfg: ModelConfig, batch: int, length: int,
               dtype=None) -> KVCache:
    k = cfg.n_kv_heads
    dt = dtype or cfg.cdtype
    return KVCache(
        k=jnp.zeros((batch, length, k, cfg.hd), dt),
        v=jnp.zeros((batch, length, k, cfg.hd), dt),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def _mask(q_pos, k_pos, window: Optional[int], cross: bool = False):
    """(..., S_q, S_k) boolean mask: causal + optional sliding window."""
    if cross:
        return None
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _sdpa(q, k, v, mask, scale):
    """q (B,S,H,hd) k/v (B,T,K,hd) — grouped by repeating kv heads."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    qg = q.reshape(B, S, K, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def attention(params, x, positions, cfg: ModelConfig, *,
              kv: Optional[tuple[jax.Array, jax.Array]] = None,
              causal: bool = True, use_kernel: bool = False,
              return_kv: bool = False):
    """Full-sequence (train/prefill) attention.  ``kv`` overrides the
    self-attention keys/values for cross-attention (enc-dec)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
        cross = False
    else:
        xkv, _ = kv
        k = jnp.einsum("btd,dhk->bthk", xkv, params["wk"])
        v = jnp.einsum("btd,dhk->bthk", xkv, params["wv"])
        cross = True
    scale = cfg.hd ** -0.5
    S = q.shape[1]
    if use_kernel and not cross and causal:
        from repro.kernels import flash_attention
        out = flash_attention.mha(q, k, v, causal=True, window=cfg.window,
                                  scale=scale)
    elif not cross and causal and S >= 1024 and S % 256 == 0:
        # chunked flash formulation: O(S·block) memory, GQA pre-repeated so
        # every tensor shards over heads (the dry-run / production jnp path)
        from .flash import flash_attention as flash_jnp
        g = q.shape[2] // k.shape[2]
        kr = jnp.repeat(k, g, axis=2) if g > 1 else k
        vr = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = flash_jnp(q, kr, vr, causal=True, window=cfg.window,
                        scale=scale)
    else:
        k_pos = jnp.arange(k.shape[1])[None] if cross else positions
        m = (_mask(positions, k_pos, cfg.window, cross)
             if causal else None)
        out = _sdpa(q, k, v, m, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def prefill_cache(k: jax.Array, v: jax.Array, cfg: ModelConfig,
                  cache_len: int) -> KVCache:
    """Pack full-sequence K/V into a decode cache (ring layout for SWA)."""
    B, S = k.shape[:2]
    C = cache_len
    if S >= C:
        # keep the last C tokens; token at original position t sits at ring
        # slot t % C, i.e. a roll of the last-C slice by S % C
        kk = jnp.roll(k[:, -C:], S % C, axis=1)
        vv = jnp.roll(v[:, -C:], S % C, axis=1)
    else:
        pad = [(0, 0), (0, C - S), (0, 0), (0, 0)]
        kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
    return KVCache(k=kk, v=vv,
                   pos=jnp.full((B,), S, jnp.int32))


def decode_attention(params, x, cache: KVCache, cfg: ModelConfig):
    """One-token decode against a KV cache.

    x: (B, 1, D).  The cache key/value time axis is the shardable dim for
    long-context decode (flash-decode style: XLA partitions the softmax
    reduction over the sharded axis with all-reduces).
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    knew = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    vnew = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    pos = cache.pos                                    # (B,)
    q = rope(q, pos[:, None], cfg.rope_theta, cfg.rope_fraction)
    knew = rope(knew, pos[:, None], cfg.rope_theta, cfg.rope_fraction)

    C = cache.k.shape[1]
    slot = (pos % C)[:, None, None, None]              # ring buffer for SWA
    idx = slot * jnp.ones((B, 1, 1, 1), jnp.int32)
    onehot = jax.nn.one_hot(idx[:, 0, 0, 0], C, dtype=cache.k.dtype)  # (B,C)
    k = cache.k * (1 - onehot[:, :, None, None]) + \
        onehot[:, :, None, None] * knew.astype(cache.k.dtype)
    v = cache.v * (1 - onehot[:, :, None, None]) + \
        onehot[:, :, None, None] * vnew.astype(cache.v.dtype)

    # valid positions: written and (if SWA) within the window
    tpos = jnp.arange(C)[None, :]                      # ring slots
    written = tpos <= jnp.minimum(pos[:, None], C - 1)
    scale = cfg.hd ** -0.5
    H, K = cfg.n_heads, cfg.n_kv_heads
    g = H // K
    qg = q.reshape(B, 1, K, g, cfg.hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(written[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    out = out.reshape(B, 1, H, cfg.hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, KVCache(k=k, v=v, pos=pos + 1)
