"""Model configuration — one dataclass covering all assigned families."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    # -- MoE --
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # -- attention flavour --
    head_dim: Optional[int] = None            # default d_model // n_heads
    window: Optional[int] = None              # sliding-window attention
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0                # chatglm applies RoPE to half
    # -- hybrid (recurrentgemma): repeating block pattern --
    block_pattern: tuple[str, ...] = ("attn",)   # e.g. ("rec","rec","attn")
    lru_width: Optional[int] = None
    conv_width: int = 4                        # temporal conv in rec blocks
    # -- encoder-decoder --
    enc_layers: int = 0                        # 0 = decoder-only
    # -- modality frontend stub --
    frontend: Optional[str] = None             # "audio" | "vision" | None
    frontend_tokens: int = 0                   # frames/patches per sample
    # -- numerics --
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logits_softcap: float = 0.0
    # -- serving --
    max_cache: int = 32_768
    # -- lowering control --
    # unroll layer scans (used by dry-run metric variants: XLA cost_analysis
    # does not descend into while-loop bodies, so per-layer costs are read
    # from shallow unrolled lowerings and extrapolated)
    scan_unroll: bool = False
    # -- distribution hints (set by the launcher, not by arch configs) --
    # sp_axis: mesh axis to sequence-shard the residual carry on between
    # blocks (Megatron-SP style); batch_axes: the activation batch axes
    sp_axis: Optional[str] = None
    batch_axes: tuple = ()

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (bounded state)."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    def param_count(self) -> int:
        """Analytic total parameter count (embedding included)."""
        from . import lm
        return lm.count_params(self)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: shared + top_k experts only)."""
        from . import lm
        return lm.count_params(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 + (len(self.block_pattern) > 1)),
            d_model=64,
            n_heads=min(self.n_heads, 4) or 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            head_dim=16 if self.n_heads else None,
            window=min(self.window, 32) if self.window else None,
            lru_width=64 if self.lru_width else None,
            enc_layers=min(self.enc_layers, 2),
            frontend_tokens=min(self.frontend_tokens, 16),
            max_cache=128,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if len(self.block_pattern) > 1:
            small["n_layers"] = len(self.block_pattern)
        small.update(overrides)
        return dataclasses.replace(self, **small)
