"""Model zoo: pattern-stacked transformers, MoE, hybrid, SSM, enc-dec, VLM."""

from .config import ModelConfig
from .api import (SHAPES, batch_axis_spec, bubble_tree, decode_specs, dims,
                  init, input_specs, make_decode_fn, make_loss_fn,
                  make_paged_decode_fn, make_prefill_fn, params_specs,
                  prefill_specs, shape_applicable, train_specs)
from . import lm, paged

__all__ = [
    "ModelConfig", "SHAPES", "batch_axis_spec", "bubble_tree", "decode_specs",
    "dims", "init", "input_specs", "make_decode_fn", "make_loss_fn",
    "make_paged_decode_fn", "make_prefill_fn", "params_specs",
    "prefill_specs", "shape_applicable", "train_specs", "lm", "paged",
]
