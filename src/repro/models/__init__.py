"""Model zoo: pattern-stacked transformers, MoE, hybrid, SSM, enc-dec, VLM."""

from .config import ModelConfig
from .api import (SHAPES, bubble_tree, decode_specs, dims, init, input_specs,
                  make_decode_fn, make_loss_fn, make_prefill_fn,
                  params_specs, prefill_specs, shape_applicable, train_specs)
from . import lm

__all__ = [
    "ModelConfig", "SHAPES", "bubble_tree", "decode_specs", "dims", "init",
    "input_specs", "make_decode_fn", "make_loss_fn", "make_prefill_fn",
    "params_specs", "prefill_specs", "shape_applicable", "train_specs", "lm",
]
