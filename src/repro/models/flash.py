"""Flash attention in pure JAX: chunked online-softmax with custom VJP.

This is the memory-honest formulation of attention the dry-run lowers
(O(S·block) live memory instead of the O(S²) materialised score matrix) and
the numerical oracle the Pallas TPU kernel (``repro.kernels.flash_attention``)
mirrors block-for-block.

Layout: q, k, v are (B, S, H, hd) with KV already repeated to H query heads
(GQA repeat happens in the caller), so every tensor shards cleanly over the
``model`` axis on the head dimension — no GQA reshape to confuse GSPMD.

The custom VJP stores only (q, k, v, out, logsumexp); the backward pass
recomputes per-block scores exactly like the flash-attention paper, so
nothing O(S²) is ever live, in either pass.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_count(s: int, blk: int) -> int:
    assert s % blk == 0, (s, blk)
    return s // blk


def _mask_block(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq_blk, Sk_blk) bool mask for one block pair."""
    m = None
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = k_pos[None, :] > (q_pos[:, None] - window)
        m = w if m is None else (m & w)
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash(q, k, v, causal: bool = True, window: Optional[int] = None,
          scale: float = 1.0, block: int = 512):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, scale, block)
    return out


def _flash_fwd_impl(q, k, v, causal, window, scale, block):
    B, S, H, hd = q.shape
    T = k.shape[1]
    nkv = _block_count(T, block)
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,S,hd)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)            # (B,H,T,hd)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    q_pos = jnp.arange(S)

    def body(carry, blk_idx):
        acc, m, l = carry
        k_blk = jax.lax.dynamic_slice_in_dim(kf, blk_idx * block, block, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(vf, blk_idx * block, block, 2)
        k_pos = blk_idx * block + jnp.arange(block)
        s_blk = jnp.einsum("bhsd,bhtd->bhst", qf, k_blk)        # (B,H,S,blk)
        msk = _mask_block(q_pos, k_pos, causal, window)
        if msk is not None:
            s_blk = jnp.where(msk[None, None], s_blk, NEG_INF)
        m_new = jnp.maximum(m, s_blk.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhst,bhtd->bhsd",
                                                     p, v_blk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, H, S, hd), jnp.float32)
    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nkv))
    lsafe = jnp.maximum(l, 1e-30)
    out = (acc / lsafe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(lsafe)                                    # (B,H,S)
    return out, lse


def _flash_fwd(q, k, v, causal, window, scale, block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, scale, block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, block, res, dout):
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    T = k.shape[1]
    nkv = _block_count(T, block)
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    do = dout.astype(jnp.float32).transpose(0, 2, 1, 3)          # (B,H,S,hd)
    of = out.astype(jnp.float32).transpose(0, 2, 1, 3)
    D = (do * of).sum(-1)                                        # (B,H,S)
    q_pos = jnp.arange(S)

    def body(dq, blk_idx):
        k_blk = jax.lax.dynamic_slice_in_dim(kf, blk_idx * block, block, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(vf, blk_idx * block, block, 2)
        k_pos = blk_idx * block + jnp.arange(block)
        s_blk = jnp.einsum("bhsd,bhtd->bhst", qf, k_blk)
        msk = _mask_block(q_pos, k_pos, causal, window)
        if msk is not None:
            s_blk = jnp.where(msk[None, None], s_blk, NEG_INF)
        p = jnp.exp(s_blk - lse[..., None])                      # (B,H,S,blk)
        dv_blk = jnp.einsum("bhst,bhsd->bhtd", p, do)
        dp = jnp.einsum("bhsd,bhtd->bhst", do, v_blk)
        ds = p * (dp - D[..., None])
        dq = dq + jnp.einsum("bhst,bhtd->bhsd", ds, k_blk) * scale
        dk_blk = jnp.einsum("bhst,bhsd->bhtd", ds, qf) * 1.0
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, H, S, hd), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(body, dq0, jnp.arange(nkv))
    dk = dk_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, T, hd)
    dv = dv_blocks.transpose(1, 2, 0, 3, 4).reshape(B, H, T, hd)
    return (dq.transpose(0, 2, 1, 3).astype(q.dtype),
            dk.transpose(0, 2, 1, 3).astype(k.dtype),
            dv.transpose(0, 2, 1, 3).astype(v.dtype))


flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=None, scale=1.0,
                    block=512):
    """Public entry: picks a block size that divides the sequence."""
    T = k.shape[1]
    blk = block
    while T % blk:
        blk //= 2
    blk = max(blk, 1)
    return flash(q, k, v, causal, window, scale, blk)
