"""Data pipeline: sharded synthetic token streams with hierarchical prefetch.

The pipeline is organised with the same bubble machinery as everything else:
the global dataset is a bubble of per-*pod* shard bubbles, each holding
per-*host* shard threads — so a data shard's affinity follows the bubble
down to the hosts that consume it (the paper's data-sharing relation applied
to input pipelines).  On a real fleet each host feeds only its local chips;
here the host dimension is simulated but the sharding arithmetic (which
global batch rows come from which shard) is exactly what a multi-host
jax.make_array_from_process_local_data deployment uses.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bubble import Bubble, bubble, thread


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_pods: int = 1
    hosts_per_pod: int = 1


class ShardedTokenStream:
    """Deterministic synthetic LM stream (zipf-ish unigram mix), sharded.

    ``shard(pod, host)`` yields only that host's rows of the global batch —
    identical rows regardless of how many hosts participate, so elastic
    re-sharding (changing host count after a failure) replays identically.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._step = 0

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.cfg.seed, step))

    def global_batch(self, step: int) -> dict:
        c = self.cfg
        rng = self._batch_rng(step)
        # zipf-flavoured unigram stream with burst structure
        base = rng.zipf(1.3, size=(c.global_batch, c.seq_len + 1))
        toks = (base % (c.vocab - 2)) + 1
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_rows(self, pod: int, host: int) -> slice:
        c = self.cfg
        n_hosts = c.n_pods * c.hosts_per_pod
        rows = c.global_batch // n_hosts
        idx = pod * c.hosts_per_pod + host
        return slice(idx * rows, (idx + 1) * rows)

    def shard(self, pod: int = 0, host: int = 0) -> Iterator[dict]:
        step = self._step
        while True:
            b = self.global_batch(step)
            s = self.host_rows(pod, host)
            yield {k: v[s] for k, v in b.items()}
            step += 1

    def bubble_tree(self) -> Bubble:
        """Pipeline-affinity bubble tree: pod shards ⊃ host shard threads."""
        c = self.cfg
        root = bubble(name="dataset")
        for p in range(c.n_pods):
            pb = bubble(name=f"pod_shard{p}", burst_level="pod")
            for h in range(c.hosts_per_pod):
                pb.insert(thread(1.0, name=f"host_shard{p}.{h}",
                                 data=f"shard{p}"))
            root.insert(pb)
        return root


class PrefetchBuffer:
    """Double-buffered prefetch: the next batch is materialised while the
    current step runs (overlap of input pipeline with compute)."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 to_device: bool = True):
        self.it = it
        self.depth = depth
        self.to_device = to_device
        self.buf: list[dict] = []
        self._fill()

    def _materialise(self, b: dict) -> dict:
        if self.to_device:
            return jax.tree.map(jnp.asarray, b)
        return b

    def _fill(self) -> None:
        while len(self.buf) < self.depth:
            self.buf.append(self._materialise(next(self.it)))

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        out = self.buf.pop(0)
        self._fill()
        return out
