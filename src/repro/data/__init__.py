from .pipeline import DataConfig, PrefetchBuffer, ShardedTokenStream

__all__ = ["DataConfig", "PrefetchBuffer", "ShardedTokenStream"]
