"""Scheduler tracing — the analysis tool the paper names as future work.

    "It will then be useful to develop analysis tools based on tracing the
    scheduler at runtime, so as to check and refine scheduling strategies."
    (paper §6)

:class:`Tracer` hooks a :class:`BubbleScheduler` (monkeypatch-free: the
scheduler calls are wrapped) and records an event stream — schedules,
bursts, sinks, steals, regenerations — with timestamps and queue levels.
``timeline()`` renders a per-cpu ASCII gantt; ``locality_report()``
aggregates where each bubble's threads actually ran versus where their
data lives (the check the paper wants: did the strategy keep affinity?).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from .bubble import Bubble, Thread
from .scheduler import BubbleScheduler


@dataclasses.dataclass
class Event:
    t: float
    cpu: int
    kind: str          # schedule | burst | sink | steal | rebalance | regenerate
    task: str
    level: Optional[str] = None
    distance: Optional[int] = None   # steal: levels crossed to the victim
    cost: float = 0.0                # steal/rebalance: penalty billed (quanta)


class Tracer:
    def __init__(self, sched: BubbleScheduler):
        self.sched = sched
        self.events: list[Event] = []
        self._wrap()

    def _wrap(self) -> None:
        sched = self.sched
        orig_next = sched.next_thread
        orig_burst = sched._burst
        orig_regen = sched.regenerate
        orig_rebalance = sched.rebalance
        tracer = self

        def next_thread(cpu, now=0.0, allow_steal=True, task_filter=None):
            steals0 = sched.stats.steals
            sinks0 = sched.stats.sinks
            t = orig_next(cpu, now, allow_steal, task_filter=task_filter)
            if sched.stats.steals > steals0:
                # the scheduler remembers its latest (victim queue, loot)
                vq, loot = sched.last_steal or (None, None)
                tracer.events.append(Event(
                    now, cpu, "steal",
                    loot.name if loot is not None else "?",
                    vq.level if vq is not None else None,
                    distance=sched.stats.last_steal_distance,
                    cost=sched.stats.last_steal_cost))
            if sched.stats.sinks > sinks0:
                lq = sched.last_queue
                tracer.events.append(Event(
                    now, cpu, "sink", "?",
                    lq.level if lq is not None else None))
            if t is not None:
                lq = sched.last_queue
                # `is not None`: an emptied RunQueue is falsy (__len__)
                tracer.events.append(Event(
                    now, cpu, "schedule", t.name,
                    lq.level if lq is not None else None))
            return t

        def _burst(b, q, now):
            tracer.events.append(Event(now, -1, "burst", b.name, q.level))
            return orig_burst(b, q, now)

        def regenerate(b, running):
            tracer.events.append(Event(0.0, -1, "regenerate", b.name))
            return orig_regen(b, running)

        def rebalance(cpu, now=0.0, level=None):
            moves = orig_rebalance(cpu, now, level)
            tracer.events.append(Event(
                now, cpu, "rebalance", f"moves={moves}", level,
                cost=sched.stats.last_rebalance_cost))
            return moves

        sched.next_thread = next_thread          # type: ignore
        sched._burst = _burst                    # type: ignore
        sched.regenerate = regenerate            # type: ignore
        sched.rebalance = rebalance              # type: ignore

    # -- reports --------------------------------------------------------------
    def schedules(self) -> list[Event]:
        return [e for e in self.events if e.kind == "schedule"]

    def steals(self) -> list[Event]:
        """Steal events: ``task`` names the loot, ``level`` the victim
        queue's hierarchy level — the audit trail for the affinity
        invariant (stolen bubbles should come from the nearest level that
        had any)."""
        return [e for e in self.events if e.kind == "steal"]

    def rebalances(self) -> list[Event]:
        """Proactive-rebalance events: ``task`` carries the move count,
        ``cost`` the bulk penalty billed to the triggering cpu."""
        return [e for e in self.events if e.kind == "rebalance"]

    def steals_by_level(self) -> dict[str, int]:
        """Steal counts per victim-queue level — the per-level view of
        steal traffic that ``SchedStats`` only totals.  Mostly-local
        levels mean the affinity invariant is holding; a fat tail at
        outer levels is the steal-thrash signature the adaptive policy's
        window watches for."""
        hist: dict[str, int] = defaultdict(int)
        for e in self.steals():
            hist[e.level or "?"] += 1
        return dict(hist)

    def steal_cost_paid(self) -> float:
        """Total steal + rebalance penalty recorded in the event stream."""
        return sum(e.cost for e in self.events
                   if e.kind in ("steal", "rebalance"))

    def timeline(self, width: int = 64) -> str:
        """Per-cpu lane of scheduled task initials over event order."""
        lanes: dict[int, list[str]] = defaultdict(list)
        for e in self.schedules():
            lanes[e.cpu].append(e.task[-1] if e.task else "?")
        out = []
        for cpu in sorted(lanes):
            lane = "".join(lanes[cpu])[:width]
            out.append(f"cpu{cpu:<3d} |{lane}")
        return "\n".join(out)

    def level_histogram(self) -> dict[str, int]:
        """At which hierarchy level did threads get picked up?  A healthy
        bubble schedule picks mostly from local levels."""
        hist: dict[str, int] = defaultdict(int)
        for e in self.schedules():
            hist[e.level or "?"] += 1
        return dict(hist)

    def locality_report(self, topo, homes: dict[str, int],
                        threads: list[Thread]) -> dict:
        """Fraction of schedules that ran a thread on its data's home
        component, per level."""
        by_thread = {t.name: t for t in threads}
        local = total = 0
        for e in self.schedules():
            t = by_thread.get(e.task)
            if t is None or t.data is None or t.data not in homes:
                continue
            total += 1
            if topo.distance_factor(e.cpu, homes[t.data]) == 1.0:
                local += 1
        return {"local": local, "total": total,
                "fraction": local / total if total else None}

    def summary(self) -> dict:
        kinds: dict[str, int] = defaultdict(int)
        for e in self.events:
            kinds[e.kind] += 1
        return dict(kinds)
