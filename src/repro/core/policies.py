"""Scheduling policies compared in the paper's evaluation (§5.2, Table 2).

* :class:`SimplePolicy` — the *opportunist* schedule: one global task list,
  Self-Scheduling with a last-cpu affinity memo (Linux 2.4 / Windows 2000
  style, paper §2.2).
* :class:`PerCpuPolicy` — per-cpu lists with steal-from-most-loaded
  (AFS/LDS, Linux 2.6 style) — an extra baseline beyond the paper's table.
* :class:`BoundPolicy` — the *predetermined* schedule: threads bound to
  cpus by hand, non-portable (paper §2.1).
* :class:`BubblePolicy` — our subject: the bubble scheduler of §3.3.
* :class:`StealPolicy` — bubbles + the hierarchical whole-bubble steal pass
  with next-touch data migration (§3.3.3 stealing made load-bearing): the
  row to compare against ``bubbles`` on *imbalanced* workloads.
* :class:`AdaptivePolicy` — stealing made cost-aware: monitors a sliding
  window of steal attempts and, past a threshold, proactively re-gathers
  and re-spreads the queued work (ARMS-style adaptive re-mapping,
  arXiv:2112.09509) instead of letting cpus drain the backlog one costed
  steal at a time — the row to compare against ``steal`` on *thrash-prone*
  workloads.

Every policy exposes the same small driver interface used by the simulator:
``submit`` (initial placement), ``next(cpu)``, ``on_yield`` (thread finished
its quantum / its cycle), ``on_barrier`` (all threads hit the barrier; the
workload re-arms them).
"""

from __future__ import annotations

import hashlib
from collections import deque
from typing import Optional

from .bubble import Bubble, Thread
from .runqueues import QueueHierarchy
from .runtime import rebalance_worth_it
from .scheduler import ZERO_COST, BubbleScheduler, StealCostModel
from .topology import Topology


def _h(*parts) -> float:
    """Deterministic pseudo-random in [0,1) — no global RNG state."""
    b = hashlib.blake2b("|".join(map(str, parts)).encode(), digest_size=8)
    return int.from_bytes(b.digest(), "big") / 2**64


class Policy:
    name = "abstract"

    def __init__(self, topo: Topology):
        self.topo = topo
        # lock domain of the last successful pick — the simulator charges
        # contention when several cpus pick from the same domain in one tick
        # ("a unique thread list for the whole machine is a bottleneck").
        self.last_domain = None

    def submit(self, root: Bubble) -> None:
        raise NotImplementedError

    def next(self, cpu: int, now: float) -> Optional[Thread]:
        raise NotImplementedError

    def on_yield(self, cpu: int, t: Thread, done: bool, now: float) -> None:
        pass

    def on_barrier(self, root: Bubble, now: float) -> None:
        """All threads finished the cycle; they are re-armed by the caller."""
        raise NotImplementedError

    def lookup_cost(self) -> tuple[int, int]:
        """(total scan steps, total lookups) — Table 1 instrumentation."""
        return (0, 1)

    def consume_cost(self) -> float:
        """Steal/rebalance penalty (in quanta) accrued since the last call.

        The simulator bills it as a stall on the calling cpu; flat-list
        policies model no migration cost and return 0."""
        return 0.0


class SimplePolicy(Policy):
    """Single global list + affinity memo limited to a scan window.

    The window models the O(1)-ish head inspection a real SS scheduler can
    afford: a cpu takes its previous thread if it sits within the first
    ``window`` entries, else it takes the head — whatever its data home.
    """

    name = "simple"

    def __init__(self, topo: Topology, window: int = 2,
                 disorder: float = 3.0):
        super().__init__(topo)
        self.queue: list[Thread] = []
        self.window = window
        self.disorder = disorder   # barrier wake-order noise, in queue slots
        self._steps = 0
        self._lookups = 0

    def submit(self, root: Bubble) -> None:
        self.queue.extend(t for t in root.threads() if t.remaining > 0)

    def next(self, cpu: int, now: float) -> Optional[Thread]:
        self._lookups += 1
        if not self.queue:
            return None
        self.last_domain = "global"
        for i, t in enumerate(self.queue[: self.window]):
            self._steps += 1
            if t.last_cpu == cpu:
                self.queue.pop(i)
                t.last_cpu = cpu
                return t
        t = self.queue.pop(0)
        t.last_cpu = cpu
        return t

    def on_barrier(self, root: Bubble, now: float) -> None:
        # barrier wake: arrival order correlates with prior placement (a
        # thread tends to wake where it slept) perturbed by wake latency —
        # modelled as a deterministic jittered sort on last_cpu.
        ts = [t for t in root.threads()]
        ts.sort(key=lambda t: (t.last_cpu or 0) +
                self.disorder * (_h(t.tid, now) - 0.5) * 2.0)
        self.queue = ts

    def lookup_cost(self) -> tuple[int, int]:
        return (self._steps, max(self._lookups, 1))


class PerCpuPolicy(Policy):
    """Per-cpu lists, steal from the most loaded (AFS/LDS; Linux 2.6)."""

    name = "percpu"

    def __init__(self, topo: Topology):
        super().__init__(topo)
        self.queues: list[list[Thread]] = [[] for _ in range(topo.n_cpus)]
        self._steps = 0
        self._lookups = 0

    def submit(self, root: Bubble) -> None:
        # new work charged to the least loaded cpu (paper §2.2)
        for t in root.threads():
            if t.remaining <= 0:
                continue
            tgt = t.last_cpu if t.last_cpu is not None else \
                min(range(len(self.queues)), key=lambda c: len(self.queues[c]))
            self.queues[tgt].append(t)

    def next(self, cpu: int, now: float) -> Optional[Thread]:
        self._lookups += 1
        self._steps += 1
        if self.queues[cpu]:
            t = self.queues[cpu].pop(0)
            t.last_cpu = cpu
            self.last_domain = f"cpu{cpu}"
            return t
        # steal from the most loaded list
        victim = max(range(len(self.queues)), key=lambda c: len(self.queues[c]))
        self._steps += len(self.queues)
        if self.queues[victim]:
            t = self.queues[victim].pop()
            t.last_cpu = cpu
            self.last_domain = f"cpu{victim}"
            return t
        return None

    def on_barrier(self, root: Bubble, now: float) -> None:
        self.submit(root)

    def lookup_cost(self) -> tuple[int, int]:
        return (self._steps, max(self._lookups, 1))


class BoundPolicy(Policy):
    """Predetermined: thread i bound to cpu i mod n — perfect but
    non-portable (the paper's *bound* row)."""

    name = "bound"

    def __init__(self, topo: Topology):
        super().__init__(topo)
        self.queues: list[list[Thread]] = [[] for _ in range(topo.n_cpus)]
        self.binding: dict[int, int] = {}

    def submit(self, root: Bubble) -> None:
        for i, t in enumerate(root.threads()):
            if t.remaining <= 0:
                continue
            cpu = self.binding.setdefault(t.tid, i % self.topo.n_cpus)
            self.queues[cpu].append(t)

    def next(self, cpu: int, now: float) -> Optional[Thread]:
        if self.queues[cpu]:
            t = self.queues[cpu].pop(0)
            t.last_cpu = cpu
            self.last_domain = f"cpu{cpu}"
            return t
        return None

    def on_barrier(self, root: Bubble, now: float) -> None:
        self.submit(root)


class BubblePolicy(Policy):
    """The paper's contribution, driving :class:`BubbleScheduler`."""

    name = "bubbles"

    def __init__(self, topo: Topology, *, respect_hints: bool = True,
                 steal: bool = True, cost_model: StealCostModel = ZERO_COST,
                 bill_model: Optional[StealCostModel] = None):
        super().__init__(topo)
        self.sched = BubbleScheduler(topo, respect_hints=respect_hints,
                                     steal=steal, cost_model=cost_model,
                                     bill_model=bill_model)
        self.root: Optional[Bubble] = None
        self.running: dict[int, Thread] = {}

    def submit(self, root: Bubble) -> None:
        self.root = root
        self.sched.wake_up_bubble(root)

    def next(self, cpu: int, now: float,
             task_filter=None) -> Optional[Thread]:
        t = self.sched.next_thread(cpu, now, task_filter=task_filter)
        if t is not None:
            self.running[cpu] = t
            lq = self.sched.last_queue
            # `is not None`: a just-drained RunQueue is falsy (__len__ == 0)
            self.last_domain = lq.comp.name if lq is not None else None
        return t

    def on_yield(self, cpu: int, t: Thread, done: bool, now: float) -> None:
        self.running.pop(cpu, None)
        self.sched.thread_returned(t)

    def on_barrier(self, root: Bubble, now: float) -> None:
        # cycle boundary = the bubble's time slice: regenerate so the whole
        # group is re-distributed coherently from its home lists (§3.3.3).
        for b in root.bubbles():
            b.burst = False
        # re-wake sub-bubbles from their home lists (affinity kept); fall
        # back to the global list for bubbles never burst.  Home queues are
        # usually *empty* at the barrier, and empty RunQueues are falsy —
        # an `or` fallback here would re-route every regeneration to the
        # global list and quietly discard all placement affinity.
        glob = self.sched.queues.global_queue()
        for b in root.children:
            if isinstance(b, Bubble):
                (glob if b.home_list is None else b.home_list).push(b)
            else:
                (glob if root.home_list is None else root.home_list).push(b)
        self.sched.stats.regenerations += 1

    def lookup_cost(self) -> tuple[int, int]:
        q = self.sched.queues
        return (q.lookup_steps, max(q.lookups, 1))

    def consume_cost(self) -> float:
        return self.sched.consume_cost()


class StealPolicy(BubblePolicy):
    """Bubbles + hierarchical work stealing + next-touch data migration.

    Scheduling-wise this is :class:`BubblePolicy` with the steal pass
    forced on; the distinguishing behaviour is memory-side: it asks the
    simulator for the **next-touch** homing policy (``preferred_data_policy``),
    so a stolen thread's first access after the migration re-homes its data
    under the thief — the paper's §2.3 migration discussion made executable.
    """

    name = "steal"
    preferred_data_policy = "next_touch"

    def __init__(self, topo: Topology, *, respect_hints: bool = True,
                 cost_model: StealCostModel = ZERO_COST,
                 bill_model: Optional[StealCostModel] = None):
        super().__init__(topo, respect_hints=respect_hints, steal=True,
                         cost_model=cost_model, bill_model=bill_model)


class AdaptivePolicy(StealPolicy):
    """Steal + cost-aware proactive rebalancing (ARMS, arXiv:2112.09509).

    :class:`StealPolicy` reacts to imbalance one steal at a time; under a
    :class:`~repro.core.scheduler.StealCostModel` each of those migrations
    pays a remote lock/latency penalty, so on thrash-prone trees (many tiny
    bubbles, oscillating load) the reactive drain itself becomes the
    bottleneck.  This policy watches a sliding window of the scheduler's
    ``steal_attempts``: each ``next()`` call appends the attempts that call
    needed, and once the window's total crosses ``threshold`` the policy
    triggers :meth:`~repro.core.scheduler.BubbleScheduler.rebalance` — one
    bulk re-gather + hierarchical re-spread of every queued task, billed
    once — instead of letting the remaining idle cpus serially steal.

    The trigger is a cost-benefit test, not a bare counter: a rebalance
    fires only when the steal penalty actually *paid* recently exceeds
    what the bulk re-placement itself would cost
    (``cost_model.rebalance_cost`` over the movable backlog).  Under
    :data:`~repro.core.scheduler.ZERO_COST` stealing is free, the test
    never passes, and this policy degrades gracefully into plain
    :class:`StealPolicy` — cost-driven decisions need a cost model.

    Two triggers fire a rebalance:

    * **in-cycle** — the window's steal attempts cross ``threshold``, the
      window's paid steal cost exceeds the rebalance cost, and at least
      ``min_backlog`` movable tasks sit on queues (the gate keeps
      end-of-cycle idle spin, where every cpu's lookup comes up empty but
      there is nothing left to move, from billing no-op rebalances);
    * **at the barrier** — the finished cycle needed ``threshold`` or more
      steal attempts and paid more steal cost than a re-spread would
      charge, so the home-list placement the barrier just restored is
      about to replay the same thrash; re-spread immediately instead of
      waiting for cpus to go idle (the ARMS "proactive" part).

    Knobs:

    * ``window`` — number of recent scheduler calls monitored;
    * ``threshold`` — steal attempts (within the window, or per cycle for
      the barrier trigger) that mean placement is fighting the load;
    * ``cooldown`` — minimum scheduler calls between in-cycle rebalances
      (defaults to ``window``), so one spike cannot trigger a storm;
    * ``min_backlog`` — movable tasks required for an in-cycle rebalance;
    * ``rebalance_level`` — topology level to re-spread across.  ``None``
      (the default) derives it from the observed steal-distance histogram
      (``SchedStats.steal_distance_hist``, the scheduler-side view of
      ``Tracer.steals_by_level()``): the modal steal distance names how
      far work is actually being dragged and the re-spread deals across
      the matching level — falling back to the level just above the
      leaves before any steal has been seen;
    * ``cost_model`` — the steal/rebalance penalties; the cost weights are
      what make proactive bulk re-placement beat serial costed steals.
    """

    name = "adaptive"

    def __init__(self, topo: Topology, *, respect_hints: bool = True,
                 window: int = 24, threshold: int = 8,
                 cooldown: Optional[int] = None, min_backlog: int = 4,
                 rebalance_level: Optional[str] = None,
                 cost_model: StealCostModel = ZERO_COST):
        super().__init__(topo, respect_hints=respect_hints,
                         cost_model=cost_model)
        self.window = window
        self.threshold = threshold
        self.cooldown = window if cooldown is None else cooldown
        self.min_backlog = min_backlog
        self.rebalance_level = rebalance_level
        self._attempts: deque[int] = deque()   # steal attempts per next() call
        self._costs: deque[float] = deque()    # steal cost paid per next() call
        self._calls_since_rebalance = self.cooldown   # start armed
        self._cycle_attempts = 0               # stats marks at the last barrier
        self._cycle_cost = 0.0

    def _rebalance(self, cpu: int, now: float) -> None:
        self.sched.rebalance(cpu, now, level=self.rebalance_level)
        self._attempts.clear()
        self._costs.clear()
        self._calls_since_rebalance = 0

    def _worth_it(self, paid: float) -> bool:
        """Cost-benefit: recent steal spend must beat the re-spread bill —
        the shared :func:`repro.core.runtime.rebalance_worth_it` test, so
        every consumer (this policy's steal-attempt window, the serving
        engine's queue-depth trigger) prices a prospective re-spread the
        same way."""
        return rebalance_worth_it(self.sched, paid,
                                  min_backlog=self.min_backlog,
                                  level=self.rebalance_level)

    def next(self, cpu: int, now: float,
             task_filter=None) -> Optional[Thread]:
        s = self.sched.stats
        attempts0, cost0 = s.steal_attempts, s.steal_cost
        t = super().next(cpu, now, task_filter)
        self._attempts.append(s.steal_attempts - attempts0)
        self._costs.append(s.steal_cost - cost0)
        if len(self._attempts) > self.window:
            self._attempts.popleft()
            self._costs.popleft()
        self._calls_since_rebalance += 1
        if (self._calls_since_rebalance >= self.cooldown
                and sum(self._attempts) >= self.threshold
                and self._worth_it(sum(self._costs))):
            self._rebalance(cpu, now)
        return t

    def on_barrier(self, root: Bubble, now: float) -> None:
        super().on_barrier(root, now)
        s = self.sched.stats
        attempts = s.steal_attempts - self._cycle_attempts
        paid = s.steal_cost - self._cycle_cost
        self._cycle_attempts, self._cycle_cost = s.steal_attempts, s.steal_cost
        if attempts >= self.threshold and self._worth_it(paid):
            # the cycle that just ended thrashed; the barrier restored the
            # same home-list placement, so re-spread before it replays
            self._rebalance(0, now)


POLICIES = {p.name: p for p in
            (SimplePolicy, PerCpuPolicy, BoundPolicy, BubblePolicy,
             StealPolicy, AdaptivePolicy)}
