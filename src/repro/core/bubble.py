"""Bubble model: the application-side structure description.

The paper (Thibault 2005) asks the application to model the general layout of
its threads as nested sets called *bubbles*.  A bubble is a coset with respect
to an affinity relation; nesting expresses refinement of one relation by
another (data sharing ⊃ collective operations ⊃ SMT symbiosis, ...).

Here a bubble tree describes any schedulable structure:

* in the **simulator** (faithful reproduction) the leaves are threads with an
  amount of work and a data-set id;
* in the **placement planner** the leaves are model components (a stack of
  attention heads, an expert, an embedding shard) with a parallel width;
* in the **serving engine** the leaves are decode requests and bubbles are
  gangs of requests that share a prefix / SLA class.

Tasks carry integer priorities (higher = more urgent, exactly as in the
paper's Figure 1) and bubbles carry a *burst level* hint naming the topology
level at which they should explode.  ``burst_level=None`` lets the scheduler
pick (the paper's "in the long run, once we get good heuristics").
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional

_ids = itertools.count()


def reset_ids() -> None:
    """Restart the task-id counter (test/bench determinism).

    Task ids seed the deterministic jitter hash, so two runs only produce
    identical traces when their trees were built from the same id origin —
    golden-trace tests call this before every run."""
    global _ids
    _ids = itertools.count()


@dataclass(eq=False)
class Task:
    """Anything that can sit on a run queue: a thread or a bubble.

    Tasks compare (and hash) by **identity**: two threads that happen to
    carry the same name/priority/work are still distinct schedulable
    entities, and queue removal must never confuse them (structural
    dataclass equality made ``deque.remove`` pull the wrong twin)."""

    name: str = ""
    prio: int = 0                      # higher wins (paper §3.3.2)
    parent: Optional["Bubble"] = None

    def __post_init__(self) -> None:
        self.tid = next(_ids)
        if not self.name:
            self.name = f"{type(self).__name__.lower()}{self.tid}"

    # -- tree queries ------------------------------------------------------
    def is_bubble(self) -> bool:
        return isinstance(self, Bubble)

    def depth(self) -> int:
        d, node = 0, self.parent
        while node is not None:
            d, node = d + 1, node.parent
        return d

    def root(self) -> "Task":
        node = self
        while node.parent is not None:
            node = node.parent
        return node


@dataclass(eq=False)
class Thread(Task):
    """A leaf task.

    ``work`` is an abstract amount of computation (simulator time units,
    FLOPs for the planner, or remaining decode tokens for serving).
    ``data`` names the data set the thread touches — threads sharing ``data``
    benefit from being scheduled under the same topology component (the
    paper's *data sharing* affinity).  ``width`` is the parallel width the
    leaf can be split across (1 for a true thread; >1 for e.g. a head-stack
    component in the planner).
    """

    work: float = 1.0
    data: Optional[str] = None
    width: int = 1
    fn: Optional[Callable[..., Any]] = None      # payload for runnable threads
    # -- mutable scheduler state --
    remaining: float = field(default=0.0)
    last_cpu: Optional[int] = None               # affinity memo (paper §2.2)
    stolen: bool = field(default=False)          # set by a steal; consumed by
                                                 # the next-touch policy (§2.3)

    def __post_init__(self) -> None:
        super().__post_init__()
        self.remaining = float(self.work)


@dataclass(eq=False)
class Bubble(Task):
    """A nested set of tasks (threads and/or bubbles).

    ``burst_level`` — name of the topology level where the bubble should
    burst ("machine", "node", "chip", ... or mesh-axis names for the
    planner).  ``None`` = scheduler's choice.
    ``timeslice`` — simulator ticks before the bubble is regenerated
    (paper §3.3.3); ``None`` disables preemptive regeneration.
    """

    children: list[Task] = field(default_factory=list)
    burst_level: Optional[str] = None
    timeslice: Optional[float] = None
    # -- mutable scheduler state --
    burst: bool = field(default=False)
    home_list: Any = field(default=None)          # list where it was released
    released_at: float = field(default=0.0)

    def __post_init__(self) -> None:
        super().__post_init__()
        for c in self.children:
            c.parent = self

    # -- construction ------------------------------------------------------
    def insert(self, task: Task) -> "Bubble":
        """paper: ``marcel_bubble_inserttask`` (Figure 4)."""
        task.parent = self
        self.children.append(task)
        return self

    # -- queries -----------------------------------------------------------
    def threads(self) -> Iterator[Thread]:
        for c in self.children:
            if isinstance(c, Bubble):
                yield from c.threads()
            else:
                yield c  # type: ignore[misc]

    def bubbles(self) -> Iterator["Bubble"]:
        yield self
        for c in self.children:
            if isinstance(c, Bubble):
                yield from c.bubbles()

    def total_work(self) -> float:
        return sum(t.remaining for t in self.threads())

    def total_width(self) -> int:
        return sum(t.width for t in self.threads())

    def n_threads(self) -> int:
        return sum(1 for _ in self.threads())

    def done(self) -> bool:
        return all(t.remaining <= 0 for t in self.threads())

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        head = f"{pad}({self.name} prio={self.prio}"
        if self.burst_level:
            head += f" burst@{self.burst_level}"
        lines = [head + ")"]
        for c in self.children:
            if isinstance(c, Bubble):
                lines.append(c.pretty(indent + 1))
            else:
                t = c  # type: ignore[assignment]
                lines.append(
                    f"{pad}  [{t.name} prio={t.prio} work={getattr(t, 'work', '?')}"
                    f" data={getattr(t, 'data', None)} w={getattr(t, 'width', 1)}]"
                )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# convenience constructors
# ---------------------------------------------------------------------------

def bubble(*children: Task, name: str = "", prio: int = 0,
           burst_level: Optional[str] = None,
           timeslice: Optional[float] = None) -> Bubble:
    return Bubble(name=name, prio=prio, children=list(children),
                  burst_level=burst_level, timeslice=timeslice)


def thread(work: float = 1.0, *, name: str = "", prio: int = 0,
           data: Optional[str] = None, width: int = 1,
           fn: Optional[Callable[..., Any]] = None) -> Thread:
    return Thread(name=name, prio=prio, work=work, data=data, width=width,
                  fn=fn)


def balanced_tree(fanouts: list[int], work: float = 1.0,
                  data_by_group: bool = True, prefix: str = "g") -> Bubble:
    """Build a uniform bubble tree: fanouts=[4,4] → 4 bubbles of 4 threads.

    Mirrors the paper's NovaScale experiment ("hence 4 bubbles of 4 threads").
    """
    def build(level: int, path: str) -> Task:
        if level == len(fanouts):
            return thread(work, name=f"t{path}",
                          data=(path.rsplit(".", 1)[0] if data_by_group else path))
        b = bubble(name=f"{prefix}{path}")
        for i in range(fanouts[level]):
            b.insert(build(level + 1, f"{path}.{i}" if path else str(i)))
        return b

    root = build(0, "")
    assert isinstance(root, Bubble)
    return root
