"""Hierarchical task lists ("run queues") — the machine-side scheduler state.

Each component of each level of the topology owns exactly one task list
(paper §3.2).  A task sitting on a list may be executed by any cpu covered by
that list's component; placing a task lower narrows its scheduling area and
increases locality, placing it higher widens load-balancing freedom.

The lookup implements the paper's two-pass scheme (§4):

* **pass 1** scans the lists covering a cpu from most local to most global,
  without locks, remembering the list holding the highest-priority task;
* **pass 2** "locks" that list and re-validates that a task of that priority
  is still there (another cpu may have raced us); on failure the scan
  restarts.

We are single-controller so locks are simulated (a claim counter) — keeping
the structure lets the simulator reproduce the paper's cost measurements
(Table 1: the *Yield* column is exactly this lookup) and models the races a
multi-controller serving deployment would see.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .bubble import Task
from .topology import Component, Topology


@dataclass
class RunQueue:
    comp: Component
    tasks: deque = field(default_factory=deque)
    version: int = 0          # bumped on every mutation (pass-2 validation)
    lock_count: int = 0       # accounting only (single controller)

    @property
    def level(self) -> str:
        return self.comp.level.name

    def push(self, task: Task, front: bool = False) -> None:
        (self.tasks.appendleft if front else self.tasks.append)(task)
        self.version += 1

    def remove(self, task: Task) -> bool:
        """Remove exactly ``task`` (identity, not equality).

        The steal path pulls tasks from *non-head* positions; removal by
        value would delete the first structurally-equal twin instead of the
        claimed object, losing one task and double-scheduling another.
        """
        for i, t in enumerate(self.tasks):
            if t is task:
                del self.tasks[i]
                self.version += 1
                return True
        return False

    def best_prio(self, task_filter=None) -> Optional[int]:
        """Highest priority present; with ``task_filter`` set, highest among
        the tasks the filter admits (the WDRR class gate of the serving
        engine's admission wave)."""
        if task_filter is None:
            return max((t.prio for t in self.tasks), default=None)
        return max((t.prio for t in self.tasks if task_filter(t)),
                   default=None)

    def pop_best(self, min_prio: Optional[int] = None,
                 task_filter=None) -> Optional[Task]:
        """Claim the highest-priority task (FIFO among equals).

        Deletion is by index so the claimed object — and not an equal-looking
        sibling nearer the head — is the one that leaves the queue, keeping
        pass-2 revalidation sound when tasks sit at non-head positions.
        ``task_filter`` restricts the claim to tasks the filter admits.
        """
        best_i, best_p = -1, None
        for i, t in enumerate(self.tasks):
            if task_filter is not None and not task_filter(t):
                continue
            if best_p is None or t.prio > best_p:
                best_i, best_p = i, t.prio
        if best_i < 0 or (min_prio is not None and best_p < min_prio):
            return None
        task = self.tasks[best_i]
        del self.tasks[best_i]
        self.version += 1
        return task

    def __len__(self) -> int:
        return len(self.tasks)


class QueueHierarchy:
    """One RunQueue per topology component + the two-pass lookup."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self.queues: dict[int, RunQueue] = {}

        def attach(comp: Component) -> None:
            self.queues[id(comp)] = RunQueue(comp)
            for c in comp.children:
                attach(c)

        attach(topo.root)
        # per-cpu covering chains, local→global, precomputed once
        self._cover = {cpu: [self.queues[id(c)] for c in topo.covering(cpu)]
                       for cpu in range(topo.n_cpus)}
        self.lookup_steps = 0        # instrumentation for Table 1
        self.lookups = 0
        self.retries = 0

    # -- elasticity ----------------------------------------------------------
    def sync(self) -> None:
        """Re-sync queues and covering chains after a Topology mutation
        (:meth:`Topology.remove_component` / ``add_component``).

        Queues of detached components must already be empty — the caller
        re-homes their tasks *before* the surgery (the serving engine folds
        them one level up, the paper's §3.3.3 regeneration move) — and are
        dropped; new components get fresh empty queues; the per-cpu covering
        chains are rebuilt from the live leaves only, so dead cpus simply
        stop being lookup entry points."""
        live: dict[int, RunQueue] = {}

        def attach(comp: Component) -> None:
            q = self.queues.get(id(comp))
            live[id(comp)] = q if q is not None else RunQueue(comp)
            for c in comp.children:
                attach(c)

        attach(self.topo.root)
        for key, q in self.queues.items():
            if key not in live:
                assert not q.tasks, \
                    f"detached queue {q.comp.name} still holds " \
                    f"{len(q.tasks)} task(s); re-home them before sync()"
        self.queues = live
        self._cover = {leaf.cpu: [self.queues[id(c)] for c in leaf.path()[::-1]]
                       for leaf in self.topo.root.leaves()}

    # -- placement ---------------------------------------------------------
    def queue_of(self, comp: Component) -> RunQueue:
        return self.queues[id(comp)]

    def global_queue(self) -> RunQueue:
        return self.queues[id(self.topo.root)]

    def covering(self, cpu: int) -> list[RunQueue]:
        return self._cover[cpu]

    # -- the paper's two-pass lookup ----------------------------------------
    def find(self, cpu: int, task_filter=None
             ) -> Optional[tuple[RunQueue, Task]]:
        """Find + claim the max-priority task among lists covering ``cpu``.

        Ties break toward the most local list (scanned first) — that is what
        gives the hierarchy its locality benefit.  Complexity is linear in
        the number of hierarchical levels (paper §4).  ``task_filter``
        narrows both passes to tasks the filter admits — the covering-list
        walk is unchanged, only ineligible tasks become invisible to it
        (the serving engine's weighted-deficit class gate rides on this).
        """
        self.lookups += 1
        while True:
            best_q, best_p, snap = None, None, 0
            for q in self._cover[cpu]:                      # pass 1, no lock
                self.lookup_steps += 1
                p = q.best_prio(task_filter)
                if p is not None and (best_p is None or p > best_p):
                    best_q, best_p, snap = q, p, q.version
            if best_q is None:
                return None
            best_q.lock_count += 1                           # pass 2, locked
            if best_q.version != snap:
                task = best_q.pop_best(min_prio=best_p,
                                       task_filter=task_filter)
                if task is None:                             # raced: restart
                    self.retries += 1
                    continue
            else:
                task = best_q.pop_best(task_filter=task_filter)
            return task and (best_q, task)

    # NOTE: stealing lives in :meth:`BubbleScheduler._steal_pass` — the
    # hierarchy only provides the queues + the two-pass lookup, so there is
    # exactly one steal implementation to keep correct.

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict[str, list[str]]:
        out = {}
        for q in self.queues.values():
            if len(q):
                out[q.comp.name] = [t.name for t in q.tasks]
        return out

    def total_tasks(self) -> int:
        return sum(len(q) for q in self.queues.values())
