"""Hierarchical task lists ("run queues") — the machine-side scheduler state.

Each component of each level of the topology owns exactly one task list
(paper §3.2).  A task sitting on a list may be executed by any cpu covered by
that list's component; placing a task lower narrows its scheduling area and
increases locality, placing it higher widens load-balancing freedom.

The lookup implements the paper's two-pass scheme (§4):

* **pass 1** scans the lists covering a cpu from most local to most global,
  without locks, remembering the list holding the highest-priority task;
* **pass 2** "locks" that list and re-validates that a task of that priority
  is still there (another cpu may have raced us); on failure the scan
  restarts.

We are single-controller so locks are simulated (a claim counter) — keeping
the structure lets the simulator reproduce the paper's cost measurements
(Table 1: the *Yield* column is exactly this lookup) and models the races a
multi-controller serving deployment would see.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .bubble import Bubble, Task
from .topology import Component, Topology


@dataclass
class RunQueue:
    comp: Component
    tasks: deque = field(default_factory=deque)
    version: int = 0          # bumped on every mutation (pass-2 validation)
    lock_count: int = 0       # accounting only (single controller)

    @property
    def level(self) -> str:
        return self.comp.level.name

    def push(self, task: Task, front: bool = False) -> None:
        (self.tasks.appendleft if front else self.tasks.append)(task)
        self.version += 1

    def remove(self, task: Task) -> bool:
        try:
            self.tasks.remove(task)
        except ValueError:
            return False
        self.version += 1
        return True

    def best_prio(self) -> Optional[int]:
        return max((t.prio for t in self.tasks), default=None)

    def pop_best(self, min_prio: Optional[int] = None) -> Optional[Task]:
        """Claim the highest-priority task (FIFO among equals)."""
        best, best_p = None, None
        for t in self.tasks:
            if best_p is None or t.prio > best_p:
                best, best_p = t, t.prio
        if best is None or (min_prio is not None and best_p < min_prio):
            return None
        self.tasks.remove(best)
        self.version += 1
        return best

    def __len__(self) -> int:
        return len(self.tasks)


class QueueHierarchy:
    """One RunQueue per topology component + the two-pass lookup + stealing."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self.queues: dict[int, RunQueue] = {}

        def attach(comp: Component) -> None:
            self.queues[id(comp)] = RunQueue(comp)
            for c in comp.children:
                attach(c)

        attach(topo.root)
        # per-cpu covering chains, local→global, precomputed once
        self._cover = {cpu: [self.queues[id(c)] for c in topo.covering(cpu)]
                       for cpu in range(topo.n_cpus)}
        self.lookup_steps = 0        # instrumentation for Table 1
        self.lookups = 0
        self.retries = 0

    # -- placement ---------------------------------------------------------
    def queue_of(self, comp: Component) -> RunQueue:
        return self.queues[id(comp)]

    def global_queue(self) -> RunQueue:
        return self.queues[id(self.topo.root)]

    def covering(self, cpu: int) -> list[RunQueue]:
        return self._cover[cpu]

    # -- the paper's two-pass lookup ----------------------------------------
    def find(self, cpu: int) -> Optional[tuple[RunQueue, Task]]:
        """Find + claim the max-priority task among lists covering ``cpu``.

        Ties break toward the most local list (scanned first) — that is what
        gives the hierarchy its locality benefit.  Complexity is linear in
        the number of hierarchical levels (paper §4).
        """
        self.lookups += 1
        while True:
            best_q, best_p, snap = None, None, 0
            for q in self._cover[cpu]:                      # pass 1, no lock
                self.lookup_steps += 1
                p = q.best_prio()
                if p is not None and (best_p is None or p > best_p):
                    best_q, best_p, snap = q, p, q.version
            if best_q is None:
                return None
            best_q.lock_count += 1                           # pass 2, locked
            if best_q.version != snap:
                task = best_q.pop_best(min_prio=best_p)
                if task is None:                             # raced: restart
                    self.retries += 1
                    continue
            else:
                task = best_q.pop_best()
            return task and (best_q, task)

    # -- stealing (HAFS-style, used by bubble regeneration) ------------------
    def steal(self, cpu: int) -> Optional[tuple[RunQueue, Task]]:
        """Idle cpu pulls a *bubble* (preferred) or thread from the most
        loaded queue outside its covering chain, nearest level first."""
        chain = set(id(q.comp) for q in self._cover[cpu])
        path = self.topo.cpus[cpu].path()            # root→leaf
        for anc in path[::-1][1:]:                   # walk upward
            candidates: list[RunQueue] = []
            for sib in anc.children:
                if id(sib) in chain:
                    continue
                for comp in self._subtree(sib):
                    q = self.queues[id(comp)]
                    if len(q):
                        candidates.append(q)
            if candidates:
                q = max(candidates, key=lambda q: sum(
                    t.total_work() if isinstance(t, Bubble)
                    else getattr(t, "remaining", 1.0) for t in q.tasks))
                # prefer whole bubbles: stealing a coherent group keeps
                # affinity intact (paper §3.3.3)
                for t in list(q.tasks):
                    if isinstance(t, Bubble):
                        q.remove(t)
                        return q, t
                t = q.pop_best()
                if t is not None:
                    return q, t
        return None

    @staticmethod
    def _subtree(comp: Component):
        yield comp
        for c in comp.children:
            yield from QueueHierarchy._subtree(c)

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict[str, list[str]]:
        out = {}
        for q in self.queues.values():
            if len(q):
                out[q.comp.name] = [t.name for t in q.tasks]
        return out

    def total_tasks(self) -> int:
        return sum(len(q) for q in self.queues.values())
