"""SchedulerRuntime: the scheduling-decision loop, shared by every consumer.

The paper's core claim is portability: *one* scheduler model (bubbles +
hierarchical runqueues) serves any workload.  Before this layer existed the
repo had drifted into two divergent consumers — the discrete
:class:`~repro.core.simulator.Simulator` owned the whole
idle→lookup→steal→bill-cost→next-touch→adaptive-rebalance loop as private
methods, while the JAX serving engine re-implemented plain admission with
none of it.  This module extracts that loop into a reusable runtime so both
(and any future consumer: the placement planner, a multi-host dispatcher)
drive the *same* distribution/adaptation logic (BubbleSched, arXiv:0706.2069;
ARMS, arXiv:2112.09509):

* :meth:`SchedulerRuntime.acquire` — one idle-cpu scheduler call: policy
  lookup (the steal pass lives inside bubble-family policies) plus the
  billing of whatever steal/rebalance penalty that call accrued
  (``Policy.consume_cost``).  The consumer decides what a quantum of cost
  *means* (a simulator stall, an engine admission-latency step);
* :meth:`SchedulerRuntime.touch` — the §2.3 data policies (``first_touch``
  / ``next_touch``): the first cpu to run a thread homes its data; a thread
  flagged ``stolen`` re-homes its data under the next toucher.  Consumers
  register ``on_data_migrate`` to give the migration a physical meaning
  (the simulator re-prices NUMA distance; the serving engine re-homes a
  gang's KV pages with a batched splice).  The capacity side is the
  ``can_accept`` callback: a consumer whose destinations have finite room
  (per-page HBM budgets) vetoes steals and rebalance placements that the
  destination could not hold, and the refusals are accounted in the
  ledger;
* :meth:`SchedulerRuntime.rebalance_worth_it` /
  :meth:`SchedulerRuntime.rebalance` — the AdaptivePolicy-style cost-benefit
  trigger as a runtime callback: a proactive bulk re-spread fires only when
  the migration penalty actually *paid* recently exceeds what the re-spread
  itself would bill over the movable backlog.  Any pressure signal can feed
  it — the simulator's steal-attempt window, the engine's decode-gang queue
  depths;
* :meth:`SchedulerRuntime.counters` — the per-consumer cost ledger: steal /
  rebalance / migration accounting read as deltas so a reused runtime
  reports each run's own activity.

The runtime is deliberately thin: it owns no clock and no execution model.
Consumers keep their own notion of time and call the runtime at their own
decision points — exactly the paper's "no global scheduling: processors just
call the scheduler code themselves" (§4).
"""

from __future__ import annotations

from typing import Callable, Optional

from .bubble import Bubble, Thread
from .scheduler import BubbleScheduler
from .topology import Topology

DATA_POLICIES = ("first_touch", "next_touch")


def rebalance_worth_it(sched: BubbleScheduler, paid: float, *,
                       min_backlog: int = 1,
                       level: Optional[str] = None,
                       scope=None, priced: bool = False) -> bool:
    """The cost-benefit test behind every proactive rebalance trigger.

    ``paid`` is the migration penalty recently spent (steal cost over a
    sliding window, for whatever pressure signal the consumer watches).
    The test passes only when that spend exceeds what one bulk re-spread
    of the current backlog would bill (``cost_model.rebalance_cost`` over
    :meth:`BubbleScheduler.queued_movable` post-expansion units) and at
    least ``min_backlog`` units are actually movable.  The base-cost
    screen runs first: under :data:`~repro.core.scheduler.ZERO_COST`
    stealing is free, ``paid`` can never cover even ``rebalance_base``,
    and the full-queue backlog walk is skipped entirely — cost-driven
    decisions need a cost model.

    ``scope`` narrows both the backlog and the prospective deal to one
    subtree (:meth:`BubbleScheduler.rebalance`'s host-local mode).
    ``priced=True`` swaps the flat per-move estimate for the
    boundary-priced :meth:`BubbleScheduler.estimate_rebalance` — on a
    DCN-tabled fleet a machine-wide re-spread then has to justify its
    ``host``/``pod`` tolls, not just its descriptor moves; on table-free
    topologies both estimates are identical, so flat consumers keep
    bit-identical trigger decisions either way.
    """
    if paid <= sched.cost_model.rebalance_base:
        return False
    if priced:
        movable, est = sched.estimate_rebalance(level, scope)
        return movable >= min_backlog and paid > est
    movable = sched.queued_movable(level, scope)
    return (movable >= min_backlog
            and paid > sched.cost_model.rebalance_cost(movable))


class SchedulerRuntime:
    """One consumer's view of the scheduling loop over a :class:`Policy`.

    ``policy`` is any object with the small driver interface of
    :class:`~repro.core.policies.Policy` (``next`` / ``on_yield`` /
    ``on_barrier`` / ``consume_cost``); bubble-family policies additionally
    expose ``.sched`` (a :class:`BubbleScheduler`), which unlocks the
    rebalance trigger and the steal/rebalance ledger.

    ``data_policy`` resolution: explicit argument > the policy's
    ``preferred_data_policy`` attribute > ``first_touch`` (the Linux/Solaris
    default, §2.3).
    """

    # per-run deltas of the scheduler's steal/rebalance accounting, so a
    # reused runtime reports each run's own activity, not cumulatives
    SCHED_COUNTERS = ("steals", "steal_attempts", "steal_refusals",
                      "steal_distance", "steal_cost", "rebalances",
                      "rebalance_moves", "rebalance_cost")

    def __init__(self, topo: Topology, policy, *,
                 data_policy: Optional[str] = None,
                 on_data_migrate: Optional[
                     Callable[[str, int, int], None]] = None,
                 can_accept: Optional[Callable[..., bool]] = None,
                 bytes_of: Optional[Callable[..., float]] = None,
                 speed_of: Optional[Callable[..., float]] = None):
        self.topo = topo
        self.policy = policy
        # memory policy: explicit arg > policy preference > first touch
        self.data_policy = data_policy or getattr(
            policy, "preferred_data_policy", "first_touch")
        assert self.data_policy in DATA_POLICIES, self.data_policy
        self.on_data_migrate = on_data_migrate
        # capacity side of the data policy: ``can_accept(cpu, task,
        # pending=())`` lets the consumer veto migrations whose
        # destination cannot hold the task's data (the serving engine's
        # per-page HBM budgets); ``pending`` carries the tasks a bulk
        # rebalance deal has already routed to the same destination.
        # Wired straight onto the scheduler's steal survey / rebalance
        # deal; refusals surface in :meth:`counters` as ``steal_refusals``.
        if can_accept is not None and self.sched is not None:
            self.sched.capacity_cb = can_accept
        # physical-cost rulers (both optional, both scheduler hooks):
        # ``bytes_of(task) -> float`` prices a migration by the bytes of
        # state it drags (bandwidth-priced level-table triples read it);
        # ``speed_of(component) -> float`` is the relative execution speed
        # of the host owning a component, read by the costed steal survey
        # and the LPT rebalance deal so work drains away from slow hosts.
        if bytes_of is not None and self.sched is not None:
            self.sched.bytes_cb = bytes_of
        if speed_of is not None and self.sched is not None:
            self.sched.speed_cb = speed_of
        self.homes: dict[str, int] = {}          # data id -> home cpu
        self.data_migrations = 0                 # next-touch re-homes done
        self.migration_log: list[tuple[str, int, int]] = []  # (data, from, to)

    # -- the decision loop ---------------------------------------------------
    def acquire(self, cpu: int, now: float = 0.0, task_filter=None
                ) -> tuple[Optional[Thread], float]:
        """One idle-cpu scheduler call.

        Runs the policy's lookup (two-pass find, bubble sink/burst, and —
        for bubble-family policies — the hierarchical steal pass and any
        adaptive rebalance) and drains the penalty that call accrued.
        Returns ``(thread_or_None, cost)``; the consumer bills ``cost`` in
        its own currency (simulated stall quanta, engine steps).

        ``task_filter`` (bubble-family policies only) makes tasks the
        filter rejects invisible to the lookup and the steal survey — the
        consumer-side admission gate behind the serving engine's SLA-class
        weighted-deficit round-robin.
        """
        if task_filter is None:
            t = self.policy.next(cpu, now)
        else:
            t = self.policy.next(cpu, now, task_filter=task_filter)
        return t, self.policy.consume_cost()

    def release(self, cpu: int, t: Thread, done: bool, now: float = 0.0
                ) -> None:
        """The thread yielded (finished its quantum, its cycle, or its
        request) — regenerated bubbles collect their running threads here."""
        self.policy.on_yield(cpu, t, done, now)

    def barrier(self, root: Bubble, now: float = 0.0) -> None:
        """All threads reached the workload's barrier; the consumer re-arms
        them — the policy's coherent re-distribution opportunity."""
        self.policy.on_barrier(root, now)

    # -- data policies (§2.3) --------------------------------------------------
    def touch(self, cpu: int, t: Thread) -> tuple[int, bool]:
        """Record that ``cpu`` touched ``t``'s data; apply the data policy.

        Returns ``(home_cpu, migrated)``.  The first toucher homes the data
        at its own position (*first touch*).  Under ``next_touch`` a thread
        flagged ``stolen`` (by the steal pass or a cross-node rebalance)
        re-homes its data at the current cpu on this touch — one-shot: the
        flag is consumed either way, so a migration is paid exactly once.
        ``migrated`` is True only for that re-homing touch; consumers charge
        their migration cost (page-copy latency, KV-splice work) then.
        """
        if t.data is None:
            t.stolen = False
            return cpu, False
        home = self.homes.setdefault(t.data, cpu)     # first touch
        if t.stolen:
            t.stolen = False                           # flag is one-shot
            if self.data_policy == "next_touch" and home != cpu:
                self.migration_log.append((t.data, home, cpu))
                self.homes[t.data] = cpu
                self.data_migrations += 1
                if self.on_data_migrate is not None:
                    self.on_data_migrate(t.data, home, cpu)
                return cpu, True
        return home, False

    # -- proactive rebalancing (cost-benefit callback) -------------------------
    @property
    def sched(self) -> Optional[BubbleScheduler]:
        """The underlying bubble scheduler, when the policy has one."""
        return getattr(self.policy, "sched", None)

    def rebalance_worth_it(self, paid: float, *, min_backlog: int = 1,
                           level: Optional[str] = None,
                           scope=None, priced: bool = False) -> bool:
        """Module-level :func:`rebalance_worth_it` over this runtime's
        scheduler; always False for flat-list policies (nothing to
        re-spread hierarchically).  ``scope``/``priced`` select the
        host-local, boundary-priced variant of the test."""
        sched = self.sched
        if sched is None:
            return False
        return rebalance_worth_it(sched, paid, min_backlog=min_backlog,
                                  level=level, scope=scope, priced=priced)

    def rebalance(self, cpu: int, now: float = 0.0,
                  level: Optional[str] = None, scope=None) -> int:
        """Trigger :meth:`BubbleScheduler.rebalance` (optionally scoped to
        one subtree — the host-local mode); the billed cost surfaces
        through the next :meth:`acquire` on the triggering cpu."""
        sched = self.sched
        if sched is None:
            return 0
        return sched.rebalance(cpu, now, level=level, scope=scope)

    # -- the cost ledger -------------------------------------------------------
    def counters(self) -> dict:
        """Current cumulative steal/rebalance accounting (zeros for
        flat-list policies).  Subtract a previous snapshot to report one
        run's own activity."""
        sched = self.sched
        if sched is None:
            return {k: 0 for k in self.SCHED_COUNTERS}
        return {k: getattr(sched.stats, k) for k in self.SCHED_COUNTERS}

    @staticmethod
    def counter_deltas(before: dict, after: dict) -> dict:
        return {k: after[k] - before[k] for k in after}

    def sched_migrations(self) -> int:
        """Thread-level cpu-migration count from the scheduler stats."""
        sched = self.sched
        return sched.stats.migrations if sched else 0
