"""Machine-side model: hierarchical topology of computing resources.

The paper models a hierarchical machine as a tree whose levels are
{machine, NUMA node, chip, core, SMT} and attaches one task list to every
component of every level (Figure 2).  We generalise:

* a :class:`Topology` is a list of :class:`Level` s, root (whole machine)
  first, leaves (schedulable processors) last;
* each level has a name, a fanout, and a *distance factor* — the relative
  cost of accessing data homed under a *different* component of this level
  (the paper's NUMA factor ≈ 3 on the NovaScale; our "DCN factor" between
  TPU pods).

Topologies are purely descriptive — the simulator, the run-queue hierarchy
and the placement planner all consume them.  TPU meshes map naturally:
``axes ("pod","data","model")`` → levels pod/data/model with leaf = chip.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterator, Optional, Sequence


@dataclass(frozen=True)
class Level:
    """One level of the machine tree.

    ``fanout`` is the number of children per component of the level above —
    either one int (uniform, the common case) or a sequence giving each
    parent component its own child count in parent-index order (ragged
    trees: e.g. a decode batch whose slot count does not divide evenly
    into KV page groups must not drop the remainder slots).
    """

    name: str
    fanout: object       # int, or Sequence[int] per parent component
    factor: float = 1.0  # cross-component access penalty (NUMA factor)

    def fanout_of(self, parent_index: int) -> int:
        if isinstance(self.fanout, int):
            return self.fanout
        return self.fanout[parent_index]


@dataclass
class Component:
    """One node of the machine tree; owns one run queue (attached later)."""

    level: Level
    index: int                      # global index within its level
    parent: Optional["Component"] = None
    children: list["Component"] = field(default_factory=list)
    # leaf-only: global cpu id
    cpu: Optional[int] = None

    @property
    def name(self) -> str:
        return f"{self.level.name}{self.index}"

    def leaves(self) -> Iterator["Component"]:
        if not self.children:
            yield self
        else:
            for c in self.children:
                yield from c.leaves()

    def path(self) -> list["Component"]:
        """Root → self."""
        out, node = [], self
        while node is not None:
            out.append(node)
            node = node.parent
        return out[::-1]


class Topology:
    """A full machine tree built from a level specification.

    ``levels[0]`` must be the root level with fanout 1 (the machine itself).
    """

    def __init__(self, levels: Sequence[Level]):
        assert levels and levels[0].fanout == 1, "root level must have fanout 1"
        self.levels = list(levels)
        self._by_level: dict[str, list[Component]] = {l.name: [] for l in levels}

        def build(depth: int, parent: Optional[Component]) -> Component:
            lvl = self.levels[depth]
            comp = Component(level=lvl, index=len(self._by_level[lvl.name]),
                             parent=parent)
            self._by_level[lvl.name].append(comp)
            if depth + 1 < len(self.levels):
                n = self.levels[depth + 1].fanout_of(comp.index)
                comp.children = [build(depth + 1, comp) for _ in range(n)]
            return comp

        self.root = build(0, None)
        self.cpus: list[Component] = list(self.root.leaves())
        for i, leaf in enumerate(self.cpus):
            leaf.cpu = i
        # name -> component, built once: component names are unique
        # (level name + per-level index) and name resolution sits on
        # consumer hot paths (scoped rebalances, ingest billing)
        self._by_name: dict[str, Component] = {
            c.name: c for comps in self._by_level.values() for c in comps}
        # -- dynamic-membership bookkeeping (inert for static topologies) --
        # version bumps on every add/remove so consumers holding derived
        # caches (covering chains, positional page maps) know to rebuild.
        self.version = 0
        # leaf cpu ids are append-only: a removed leaf's id is never reused
        # or renumbered, so consumer arrays indexed by cpu id stay valid.
        self.dead_cpus: set[int] = set()
        # per-level monotone name counters: a new component never reuses a
        # dead one's name (``host1`` killed stays dead; the next join is
        # ``host2``), so stale handles fail loudly instead of aliasing.
        self._next_index: dict[str, int] = {
            name: len(comps) for name, comps in self._by_level.items()}

    # -- dynamic membership --------------------------------------------------
    def remove_component(self, name: str) -> list[Component]:
        """Detach component ``name`` (and its whole subtree) from the tree.

        The component leaves ``components()``/``component()`` resolution —
        a stale handle raises ``KeyError`` — and its leaves join
        ``dead_cpus`` (their ids remain valid indices into ``cpus`` so
        id-addressed consumer state survives, but they no longer appear in
        ``root.leaves()``).  Detached components keep their ``parent``
        pointers, so ``path()`` *from* a dead leaf still climbs into the
        live tree — ``common_level``/``distance_factor`` price a migration
        away from a dead region as an outermost-boundary crossing instead
        of crashing.  Returns the detached components, subtree-root first.
        """
        comp = self.component(name)
        assert comp.parent is not None, "cannot remove the root"
        assert len(self._by_level[comp.level.name]) > 1, \
            f"cannot remove the last {comp.level.name} component"
        comp.parent.children.remove(comp)
        removed: list[Component] = []

        def drop(c: Component) -> None:
            removed.append(c)
            self._by_level[c.level.name].remove(c)
            del self._by_name[c.name]
            if c.cpu is not None:
                self.dead_cpus.add(c.cpu)
            for ch in c.children:
                drop(ch)

        drop(comp)
        self._refresh_levels()
        self.version += 1
        return removed

    def add_component(self, level: str, fanout,
                      parent: Optional[Component] = None) -> Component:
        """Grow a new component at ``level`` under ``parent`` (default: the
        first live component of the level above).

        ``fanout`` gives the child count for each level *below* ``level``,
        outermost first — an int when only one level lies below, else a
        sequence with one entry per sub-level.  Each entry is an int
        (uniform) or a sequence consumed left-to-right per parent built at
        that depth (ragged subtrees, matching :class:`Level`'s ragged
        fanout).  New leaves get fresh cpu ids appended after every id
        ever issued — existing ids never renumber.  Returns the new
        component; its auto-assigned ``name`` is the consumer's handle.
        """
        li = self.level_index(level)
        assert li > 0, "cannot add a second root"
        below = self.levels[li + 1:]
        fans = [fanout] if isinstance(fanout, int) else list(fanout)
        assert len(fans) == len(below), \
            f"fanout needs {len(below)} entries for levels " \
            f"{[l.name for l in below]}, got {len(fans)}"
        ragged = [None if isinstance(f, int) else list(f) for f in fans]
        if parent is None:
            above = self._by_level[self.levels[li - 1].name]
            assert above, f"no live parent at level {self.levels[li - 1].name}"
            parent = above[0]
        assert parent.level.name == self.levels[li - 1].name, \
            f"parent {parent.name} is not at level {self.levels[li - 1].name}"

        def grow(depth: int, par: Optional[Component]) -> Component:
            lvl = self.levels[depth]
            idx = self._next_index[lvl.name]
            self._next_index[lvl.name] += 1
            comp = Component(level=lvl, index=idx, parent=par)
            self._by_level[lvl.name].append(comp)
            self._by_name[comp.name] = comp
            k = depth - li
            if k < len(fans):
                n = fans[k] if ragged[k] is None else ragged[k].pop(0)
                comp.children = [grow(depth + 1, comp) for _ in range(n)]
            else:
                comp.cpu = len(self.cpus)
                self.cpus.append(comp)
            return comp

        new = grow(li, parent)
        parent.children.append(new)
        self._refresh_levels()
        self.version += 1
        return new

    def _refresh_levels(self) -> None:
        """Re-derive each level's fanout from the live tree so
        ``describe()`` stays truthful after add/remove.  Level objects are
        frozen, so changed ones are replaced; components keep their
        original references — ``name`` and ``factor``, the only fields
        queries read off a component's level, never change."""
        new_levels = [self.levels[0]]
        for up, lvl in zip(self.levels, self.levels[1:]):
            sizes = [len(p.children) for p in self._by_level[up.name]]
            if not sizes:
                new_levels.append(lvl)
                continue
            fan = sizes[0] if len(set(sizes)) == 1 else sizes
            new_levels.append(lvl if fan == lvl.fanout else
                              replace(lvl, fanout=fan))
        self.levels = new_levels

    # -- queries -----------------------------------------------------------
    @property
    def n_cpus(self) -> int:
        """Total leaf ids ever issued — dead leaves included, so this stays
        the right length for cpu-id-indexed consumer arrays."""
        return len(self.cpus)

    def live_cpus(self) -> list[int]:
        """Cpu ids of the leaves still attached to the tree, in tree order."""
        return [leaf.cpu for leaf in self.root.leaves()]

    def components(self, level: str) -> list[Component]:
        return self._by_level[level]

    def component(self, name: str) -> Component:
        """Look a component up by its unique name (``level.name + index``,
        e.g. ``"host1"``, ``"page3"``) — the handle consumers use to scope
        a rebalance or home a submission to one subtree."""
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"unknown component {name!r} "
                           f"({self.describe()})") from None

    def level_names(self) -> list[str]:
        return [l.name for l in self.levels]

    def level_index(self, name: str) -> int:
        return self.level_names().index(name)

    def covering(self, cpu: int) -> list[Component]:
        """Components whose lists 'cover' this cpu — local→global order.

        The paper's lookup walks "from the most local one to the most global
        one" (§3.3.2); we return that order.
        """
        return self.cpus[cpu].path()[::-1]

    def common_level(self, cpu_a: int, cpu_b: int) -> Level:
        """Deepest level under which both cpus sit (for distance factors)."""
        pa, pb = self.cpus[cpu_a].path(), self.cpus[cpu_b].path()
        last = pa[0].level
        for a, b in zip(pa, pb):
            if a is not b:
                return last
            last = a.level
        return last

    def distance_factor(self, cpu: int, home_cpu: int) -> float:
        """Access-cost multiplier for cpu touching data homed at home_cpu.

        1.0 when they share the innermost component; otherwise the factor of
        the deepest level they do NOT share — e.g. 3.0 across NUMA nodes on
        the paper's NovaScale.
        """
        if cpu == home_cpu:
            return 1.0
        pa = self.cpus[cpu].path()
        pb = self.cpus[home_cpu].path()
        for a, b in zip(pa, pb):
            if a is not b:
                return a.level.factor
        return 1.0

    def crossing_level(self, cpu: int, comp: Component) -> Optional[str]:
        """Name of the outermost boundary a migration from ``comp``'s list
        to ``cpu`` crosses, or ``None`` when the list covers the cpu.

        This is the level of the first differing component on the two
        root→leaf paths — the same divergence point :meth:`distance_factor`
        prices.  A :class:`~repro.core.scheduler.StealCostModel` with a
        per-level penalty table looks the boundary up to price the steal:
        crossing a ``host`` (DCN traffic) is categorically more expensive
        than crossing a ``page`` (on-chip KV shuffle), not just linearly
        further away.
        """
        return self.crossing_between(self.cpus[cpu], comp)

    def crossing_between(self, a: Component, b: Component) -> Optional[str]:
        """Outermost boundary between two components of the tree, or
        ``None`` when one covers the other (an ancestor's list is reachable
        without crossing anything).

        The comp↔comp generalisation of :meth:`crossing_level`: a bulk
        rebalance prices each move by the boundary between the *source
        queue's* component and the *destination* component — a unit dealt
        from one host's page list to a sibling page crosses ``page``; dealt
        to another host it crosses ``host`` (DCN); folded back onto the
        global list it crosses nothing.
        """
        pa, pb = a.path(), b.path()
        for x, y in zip(pa, pb):
            if x is not y:
                return x.level.name
        return None

    def ancestor_at(self, comp: Component, level: str) -> Optional[Component]:
        """``comp``'s ancestor (or itself) at ``level``, or ``None`` when the
        component sits *above* that level — the machine-wide lists a
        per-host property (speed, budget) cannot be pinned to.

        This is how a consumer maps any queue component to its owning
        machine region: the serving engine resolves a page group, a slot,
        or a host list to the host whose execution speed prices it.
        """
        for node in comp.path():
            if node.level.name == level:
                return node
        return None

    def levels_crossed(self, cpu: int, comp: Component) -> int:
        """Hierarchy levels a migration from ``comp``'s list crosses to
        reach ``cpu``.

        0 when the list covers the cpu (pulling from your own covering
        chain is free); otherwise the number of tree levels between the
        cpu's leaf and the deepest ancestor it shares with ``comp`` — 1
        for a sibling cpu's list, 2 across NUMA nodes on the NovaScale.
        The steal-cost model scales its latency penalty by this distance:
        remote lock traffic and cache/page movement grow with every level
        crossed (BubbleSched's migration-cost argument, arXiv:0706.2069).
        """
        path = self.cpus[cpu].path()
        if comp in path:
            return 0
        shared = 0
        for a, b in zip(path, comp.path()):
            if a is not b:
                break
            shared += 1
        return len(path) - shared

    def describe(self) -> str:
        parts = []
        for l in self.levels:
            fan = l.fanout if isinstance(l.fanout, int) else \
                "/".join(map(str, l.fanout))
            parts.append(f"{l.name}(x{fan}" +
                         (f", factor={l.factor}" if l.factor != 1.0 else "") +
                         ")")
        dead = f" ({len(self.dead_cpus)} dead)" if self.dead_cpus else ""
        return " > ".join(parts) + f" = {self.n_cpus} cpus" + dead


# ---------------------------------------------------------------------------
# canned topologies
# ---------------------------------------------------------------------------

def novascale_16() -> Topology:
    """The paper's evaluation machine: ccNUMA Bull NovaScale, 16 Itanium II,
    4 NUMA nodes, NUMA factor ≈ 3 (§5.2)."""
    return Topology([
        Level("machine", 1),
        Level("node", 4, factor=3.0),
        Level("cpu", 4),
    ])


def bi_xeon_ht() -> Topology:
    """The paper's Fig 5(a) machine: 2 HyperThreaded Pentium IV Xeons.

    The chip-crossing factor models the cost of losing L2-cache sharing
    between the sibling hyperthreads (FSB round-trips on every miss) —
    the Netburst-era penalty is large, ≈2.5× on cache-hot codes.
    """
    return Topology([
        Level("machine", 1),
        Level("chip", 2, factor=2.5),
        Level("smt", 2, factor=1.1),
    ])


def numa_4x4_smt() -> Topology:
    """Figure 2's high-depth machine: 2 nodes x 2 chips x 2 cores x 2 SMT."""
    return Topology([
        Level("machine", 1),
        Level("node", 2, factor=3.0),
        Level("chip", 2, factor=1.4),
        Level("core", 2, factor=1.1),
        Level("smt", 2, factor=1.02),
    ])


def tpu_pod_slice(pods: int = 1, data: int = 16, model: int = 16,
                  dcn_factor: float = 12.0, ici_factor: float = 2.5) -> Topology:
    """TPU fleet hierarchy matching the production meshes.

    Leaf = chip.  ``dcn_factor`` is the pod-crossing penalty (DCN vs ICI
    bandwidth ratio ≈ 50GB/s·links vs data-center network), the direct
    analogue of the paper's NUMA factor.
    """
    levels = [Level("job", 1)]
    if pods > 1:
        levels.append(Level("pod", pods, factor=dcn_factor))
    levels += [Level("data", data, factor=ici_factor),
               Level("model", model, factor=1.0)]
    return Topology(levels)


def from_mesh_axes(axis_names: Sequence[str], axis_sizes: Sequence[int],
                   factors: Optional[Sequence[float]] = None) -> Topology:
    """Build a Topology mirroring a jax mesh's axis hierarchy (outer→inner)."""
    if factors is None:
        # outermost axes are the most expensive to cross
        defaults = {"pod": 12.0, "data": 2.5, "model": 1.0}
        factors = [defaults.get(n, 2.0) for n in axis_names]
    levels = [Level("job", 1)] + [
        Level(n, s, factor=f)
        for n, s, f in zip(axis_names, axis_sizes, factors)
    ]
    return Topology(levels)
