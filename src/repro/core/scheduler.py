"""The bubble scheduler: marrying the bubble tree and the queue hierarchy.

Implements §3.3 of the paper:

* **bubble evolution** — a woken bubble starts on the global list, sinks
  through the hierarchy toward its burst level, then bursts, releasing its
  children onto the list where it burst (Figure 3);
* **priorities** — cpus schedule the highest-priority task among the lists
  covering them, even if less-prioritised tasks are more local (§3.3.2);
* **regeneration** — after a bubble's time slice, its threads are pulled
  back in, the bubble closes and is pushed back on its home list (§3.3.3);
  idle cpus may steal whole bubbles, keeping affinity intact.

The scheduler is driven from the outside (the simulator, the serving engine,
or the placement planner): there is "no global scheduling: processors just
call the scheduler code themselves" (§4).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .bubble import Bubble, Task, Thread
from .runqueues import QueueHierarchy, RunQueue
from .topology import Component, Topology


@dataclass
class SchedStats:
    bursts: int = 0
    sinks: int = 0
    regenerations: int = 0
    steals: int = 0
    migrations: int = 0          # thread ran on a different cpu than last time
    schedules: int = 0


class BubbleScheduler:
    """Per-cpu scheduling over a :class:`QueueHierarchy`.

    ``auto_burst_threshold`` drives the default burst heuristic: a bubble
    sinks while it still fits (thread-width-wise) under one component of the
    next level down, and bursts once sinking further would leave cpus of the
    current component idle.  An explicit ``bubble.burst_level`` overrides the
    heuristic — the paper's "stricter guiding hints".
    """

    def __init__(self, topo: Topology, *, respect_hints: bool = True):
        self.topo = topo
        self.queues = QueueHierarchy(topo)
        self.respect_hints = respect_hints
        self.stats = SchedStats()
        self.last_queue: Optional[RunQueue] = None   # lock-domain of last pick

    # -- application API (paper Figure 4) ------------------------------------
    def wake_up_bubble(self, b: Bubble, at: Optional[RunQueue] = None) -> None:
        q = at or self.queues.global_queue()
        b.home_list = q
        q.push(b)

    def submit_thread(self, t: Thread) -> None:
        self.queues.global_queue().push(t)

    # -- burst-level decision --------------------------------------------------
    def _should_burst(self, b: Bubble, q: RunQueue, cpu: int) -> bool:
        if self.respect_hints and b.burst_level is not None:
            return q.level == b.burst_level or self._is_leaf(q)
        if self._is_leaf(q):
            return True
        # heuristic: burst once the bubble can no longer sink without
        # shrinking its scheduling area below its parallel width
        child = self._child_toward(q.comp, cpu)
        return child is None or b.total_width() > self._capacity(child)

    def _is_leaf(self, q: RunQueue) -> bool:
        return not q.comp.children

    @staticmethod
    def _capacity(comp: Component) -> int:
        return sum(1 for _ in comp.leaves())

    def _child_toward(self, comp: Component, cpu: int) -> Optional[Component]:
        """The child of ``comp`` on the path toward ``cpu``."""
        path = self.topo.cpus[cpu].path()
        try:
            i = path.index(comp)
        except ValueError:
            return None
        return path[i + 1] if i + 1 < len(path) else None

    # -- the scheduler entry point ----------------------------------------------
    def next_thread(self, cpu: int, now: float = 0.0,
                    allow_steal: bool = True) -> Optional[Thread]:
        """Called by an (idle or preempting) cpu.  Returns a runnable thread.

        While looking for threads, also "pulls down" bubbles from high list
        levels and makes them burst on a more local level (§4).
        """
        for _ in range(64 * len(self.topo.levels)):       # progress bound
            found = self.queues.find(cpu)
            if found is None:
                if allow_steal:
                    stolen = self.queues.steal(cpu)
                    if stolen is not None:
                        _, task = stolen
                        self.stats.steals += 1
                        # re-home the stolen task near us and retry
                        self._place_near(task, cpu)
                        allow_steal = True
                        continue
                return None
            q, task = found
            self.last_queue = q
            if isinstance(task, Thread):
                self.stats.schedules += 1
                if task.last_cpu is not None and task.last_cpu != cpu:
                    self.stats.migrations += 1
                task.last_cpu = cpu
                return task
            b = task
            if b.done():
                continue
            if self._should_burst(b, q, cpu):
                self._burst(b, q, now)
            else:
                child = self._child_toward(q.comp, cpu)
                assert child is not None
                self.queues.queue_of(child).push(b)
                self.stats.sinks += 1
        return None

    def _burst(self, b: Bubble, q: RunQueue, now: float) -> None:
        b.burst = True
        b.home_list = q
        b.released_at = now
        for c in b.children:
            if isinstance(c, Thread) and c.remaining <= 0:
                continue
            q.push(c)
        self.stats.bursts += 1

    def _place_near(self, task: Task, cpu: int) -> None:
        """Place a stolen task on the closest list that can hold it."""
        chain = self.queues.covering(cpu)                 # local → global
        if isinstance(task, Bubble):
            width = task.total_width()
            for q in chain:
                if self._capacity(q.comp) >= width or q is chain[-1]:
                    q.push(task, front=True)
                    return
        chain[0].push(task, front=True)

    # -- regeneration (§3.3.3) ---------------------------------------------------
    def regenerate(self, b: Bubble, running: dict[int, Thread]) -> None:
        """Close a burst bubble: pull its tasks off all queues, push the
        closed bubble back at the end of its home list.

        Threads currently being executed "go back in the bubble by
        themselves" — the simulator calls :meth:`thread_returned` when a
        running thread next yields.
        """
        if not b.burst:
            return
        live = set(id(t) for t in running.values())
        for sub in b.bubbles():
            for q in self.queues.queues.values():
                for t in list(q.tasks):
                    if t.parent is sub and id(t) not in live:
                        q.remove(t)
            sub.burst = False
        self.stats.regenerations += 1
        home = b.home_list or self.queues.global_queue()
        b.waiting_running = [t for t in b.threads()
                             if id(t) in live and t.remaining > 0]
        if not b.waiting_running:
            home.push(b)
        else:
            b.pending_home = home

    def thread_returned(self, t: Thread) -> None:
        """A running thread yielded after its bubble was regenerated."""
        b = t.parent
        while b is not None:
            wr = getattr(b, "waiting_running", None)
            if wr and t in wr:
                wr.remove(t)
                if not wr:
                    getattr(b, "pending_home").push(b)
            b = b.parent
