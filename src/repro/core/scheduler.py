"""The bubble scheduler: marrying the bubble tree and the queue hierarchy.

Implements §3.3 of the paper:

* **bubble evolution** — a woken bubble starts on the global list, sinks
  through the hierarchy toward its burst level, then bursts, releasing its
  children onto the list where it burst (Figure 3);
* **priorities** — cpus schedule the highest-priority task among the lists
  covering them, even if less-prioritised tasks are more local (§3.3.2);
* **regeneration** — after a bubble's time slice, its threads are pulled
  back in, the bubble closes and is pushed back on its home list (§3.3.3);
* **hierarchical work stealing** — §3.3.3's "idle cpus may steal whole
  bubbles, keeping affinity intact", made concrete: a cpu whose two-pass
  lookup comes back empty walks its covering levels **local → global**
  (:meth:`Topology.covering` order), so the *level* it steals from is the
  closest one holding any work.  Within that level it prefers a whole
  closed bubble — a coherent affinity group — over any lone thread, and
  among bubbles takes the one with the most remaining work (steal enough
  to stay busy); threads are the fallback when the level holds no bubble.
  The loot is re-pushed onto the nearest list wide enough to hold it
  (:meth:`BubbleScheduler._place_near`), so the stolen group's new
  scheduling area is the thief's neighbourhood, not one distant cpu.
  Every stolen thread is flagged ``stolen`` so a next-touch memory policy
  (simulator §2.3) can re-home its data after the migration.

Steal activity is accounted in :class:`SchedStats` (``steals``,
``bubble_steals``, ``thread_steals``, ``steal_attempts``, ``stolen_work``)
and the victim of the latest steal is kept in ``last_steal`` for tracing.

The scheduler is driven from the outside (the simulator, the serving engine,
or the placement planner): there is "no global scheduling: processors just
call the scheduler code themselves" (§4).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .bubble import Bubble, Task, Thread
from .runqueues import QueueHierarchy, RunQueue
from .topology import Component, Topology


@dataclass(frozen=True)
class StealCostModel:
    """The cost side of migration decisions (BubbleSched, arXiv:0706.2069).

    Stealing keeps cpus busy but is not free: the thief takes remote list
    locks and the loot's threads drag cold caches / remote pages behind
    them.  Every successful steal charges the thief

        ``lock_penalty + per_level * levels_crossed
                       + thread_penalty * live_threads_moved``

    in simulator quanta (:meth:`Topology.levels_crossed` is the distance).
    ``per_level`` defaults to the uniform ``level_penalty``; a non-uniform
    machine prices each boundary separately through ``level_table``, a
    tuple of ``(level_name, penalty)`` pairs — or ``(level_name, base,
    per_byte)`` triples — looked up by the *boundary* the steal crosses
    (:meth:`Topology.crossing_level`) — on a pod-sharded serving fleet a
    ``host`` crossing pays DCN round-trips and a ``pod`` crossing pays the
    data-center network, an order of magnitude over the on-chip ``page``
    shuffle, exactly the paper's NUMA-factor argument applied to the cost
    side.  Levels absent from the table fall back to ``level_penalty``.

    The triple form is **bandwidth pricing**: a crossing's bill is no
    longer a flat latency toll but ``base + per_byte * bytes_moved`` — the
    bytes are whatever state the migration drags behind it (on the serving
    fleet: a gang's live KV, ``kv_bytes`` x live threads, supplied by the
    consumer through ``BubbleScheduler.bytes_cb``).  A fat gang dragged
    across a DCN boundary then costs proportionally more than a singleton
    at the same distance.  Pair entries are exactly triples with
    ``per_byte = 0``, so every pre-bandwidth table — and every golden
    trace — prices bit-identically.

    A proactive rebalance (:meth:`BubbleScheduler.rebalance`) charges
    ``rebalance_base`` once plus, per task re-placed,

        ``rebalance_per_move + level_table[boundary crossed by the move]``

    to the cpu that triggered it — bulk re-placement amortises the
    lock/latency cost that serial stealing pays per migration, but a move
    that drags a unit across a *tabled* boundary (a ``host`` on the serving
    fleet: DCN traffic) still pays that boundary's price
    (:meth:`rebalance_move_cost`).  Unlike the steal side there is **no**
    ``level_penalty`` fallback for rebalance moves: boundaries absent from
    the table add nothing, so every flat-topology (and single-host) bill is
    exactly the historical ``rebalance_base + rebalance_per_move * moves``.
    The defaults are all zero, so unconfigured schedulers reproduce the
    PR 1 golden traces bit-for-bit.

    All prices are in the consumer's own currency — simulator stall quanta
    for the discrete :class:`~repro.core.simulator.Simulator`, engine
    admission-latency *steps* for the serving engine.
    """

    lock_penalty: float = 0.0        # flat cost per successful steal
    level_penalty: float = 0.0       # per hierarchy level crossed
    thread_penalty: float = 0.0      # per live thread moved
    rebalance_base: float = 0.0      # flat cost per proactive rebalance
    rebalance_per_move: float = 0.0  # per task re-placed by a rebalance
    # ((level_name, base), ...) or ((level_name, base, per_byte), ...):
    # boundary-specific pricing — a tuple of pairs/triples, not a dict, so
    # the dataclass stays frozen/hashable.  Pairs mean per_byte = 0.
    level_table: tuple = ()

    def _table_entry(self, boundary: Optional[str]
                     ) -> Optional[tuple[float, float]]:
        """``(base, per_byte)`` for a tabled boundary, ``None`` otherwise.
        Normalises pair entries to ``per_byte = 0`` so both table forms
        price identically everywhere downstream."""
        if boundary is not None:
            for entry in self.level_table:
                if entry[0] == boundary:
                    return (entry[1], entry[2] if len(entry) > 2 else 0.0)
        return None

    def level_cost(self, boundary: Optional[str]) -> float:
        """Per-level *base* penalty for a steal crossing ``boundary`` (the
        outermost level the migration crosses); uniform fallback."""
        entry = self._table_entry(boundary)
        return entry[0] if entry is not None else self.level_penalty

    def byte_cost(self, boundary: Optional[str]) -> float:
        """Per-byte price of dragging state across ``boundary`` — zero for
        un-tabled boundaries and pair entries (flat pricing)."""
        entry = self._table_entry(boundary)
        return entry[1] if entry is not None else 0.0

    def steal_cost(self, distance: int, n_threads: int,
                   boundary: Optional[str] = None,
                   bytes_moved: float = 0.0) -> float:
        return (self.lock_penalty + self.level_cost(boundary) * distance +
                self.thread_penalty * n_threads +
                self.byte_cost(boundary) * bytes_moved)

    def rebalance_cost(self, moves: int) -> float:
        """Flat (boundary-blind) price of a ``moves``-unit re-spread — the
        *floor* of what :meth:`BubbleScheduler.rebalance` can bill, reached
        when no move crosses a tabled boundary.  The cost-benefit trigger
        uses this as its optimistic estimate; the boundary-priced estimate
        lives in :meth:`BubbleScheduler.estimate_rebalance`."""
        return self.rebalance_base + self.rebalance_per_move * moves

    def rebalance_move_cost(self, boundary: Optional[str] = None,
                            bytes_moved: float = 0.0) -> float:
        """Price of ONE rebalance move crossing ``boundary``: the flat
        per-move descriptor cost plus the boundary's ``level_table`` base
        plus its per-byte price times the bytes the move drags.

        Table-only, deliberately: a rebalance move inside an un-tabled
        region (page→page on one host, or anywhere on a single-host fleet)
        costs exactly ``rebalance_per_move``, keeping every pre-table
        schedule's bill — and golden trace — byte-identical.  Only the
        boundaries the machine actually prices (``host``/``pod`` DCN
        crossings) add their toll."""
        entry = self._table_entry(boundary)
        if entry is None:
            return self.rebalance_per_move
        return self.rebalance_per_move + entry[0] + entry[1] * bytes_moved

    @property
    def steals_are_free(self) -> bool:
        """True when every per-steal penalty is zero — the steal pass then
        keeps its historical heaviest-loot-per-level selection (golden
        traces depend on it); any nonzero penalty switches victim
        selection to work-per-cost ranking."""
        return not (self.lock_penalty or self.level_penalty
                    or self.thread_penalty
                    or any(p for entry in self.level_table
                           for p in entry[1:]))


ZERO_COST = StealCostModel()


@dataclass
class SchedStats:
    bursts: int = 0
    sinks: int = 0
    regenerations: int = 0
    steals: int = 0              # successful steals (bubbles + threads)
    bubble_steals: int = 0       # whole affinity groups moved intact
    thread_steals: int = 0       # lone-thread fallback steals
    steal_attempts: int = 0      # steal passes entered (incl. empty-handed)
    steal_refusals: int = 0      # candidates skipped: destination full
    stolen_work: float = 0.0     # remaining work moved by steals
    migrations: int = 0          # thread ran on a different cpu than last time
    schedules: int = 0
    # -- cost accounting (StealCostModel) --
    steal_cost: float = 0.0      # total lock/latency penalty paid for steals
    steal_distance: int = 0      # total levels crossed by successful steals
    # per-distance steal counts (the Tracer's steals_by_level(), scheduler-
    # side): the observed steal-distance histogram the adaptive spread-level
    # derivation reads — a fat tail at long distances means cross-node
    # thrash, a local mode means sibling-level churn
    steal_distance_hist: dict = field(default_factory=dict)
    stolen_threads: int = 0      # live threads moved by successful steals
    rebalances: int = 0          # proactive re-spread events
    rebalance_moves: int = 0     # tasks moved by rebalances
    rebalance_cost: float = 0.0  # penalty paid for rebalances
    last_steal_distance: int = 0  # distance of the latest steal (tracing)
    last_steal_cost: float = 0.0  # cost of the latest steal (tracing)
    last_rebalance_moves: int = 0  # moves of the latest rebalance (tracing)
    last_rebalance_cost: float = 0.0  # billed cost of the latest rebalance
    # destination-side share of the latest rebalance bill: component name →
    # summed level-table extras of the moves dealt INTO it.  Billing-
    # relevant only when the consumer opted into
    # ``BubbleScheduler.ingest_billing`` (the serving engine, which stalls
    # the receiving page group's admissions for these transfer tolls —
    # consume_cost() then returns the flat trigger-side part only);
    # otherwise the trigger cpu is billed everything and this is pure
    # tracing.  Empty on any table-free model.
    last_rebalance_ingest: dict = field(default_factory=dict)


class BubbleScheduler:
    """Per-cpu scheduling over a :class:`QueueHierarchy`.

    ``auto_burst_threshold`` drives the default burst heuristic: a bubble
    sinks while it still fits (thread-width-wise) under one component of the
    next level down, and bursts once sinking further would leave cpus of the
    current component idle.  An explicit ``bubble.burst_level`` overrides the
    heuristic — the paper's "stricter guiding hints".
    """

    def __init__(self, topo: Topology, *, respect_hints: bool = True,
                 steal: bool = True, cost_model: StealCostModel = ZERO_COST,
                 bill_model: Optional[StealCostModel] = None):
        self.topo = topo
        self.queues = QueueHierarchy(topo)
        self.respect_hints = respect_hints
        self.steal = steal                           # idle cpus may steal
        self.cost_model = cost_model                 # decision-side pricing
        # what a migration *actually* costs.  Victim selection and the
        # rebalance trigger consult ``cost_model`` (what the scheduler
        # believes); the ledger bills ``bill_model`` (what the machine
        # charges).  They default to the same table — splitting them models
        # a mispriced scheduler, e.g. a DCN-naive engine that ranks victims
        # with flat per-level costs yet pays real cross-host latency.
        self.bill_model = bill_model if bill_model is not None else cost_model
        # consumer veto on destinations: ``capacity_cb(cpu, task, pending)
        # -> bool`` (always called with all three args) answers whether
        # the area around ``cpu`` can hold the loot on top of ``pending``
        # (tasks a bulk rebalance deal has already routed there before the
        # consumer's own ledger sees them; steals pass an empty tuple).  A
        # full destination *refuses* — the steal survey skips the
        # candidate (counted in ``stats.steal_refusals``) and a rebalance
        # deals the unit elsewhere, instead of dragging state somewhere it
        # cannot be admitted.
        self.capacity_cb = None
        # consumer ruler for bandwidth pricing: ``bytes_cb(task) -> float``
        # answers how many bytes of state a migration of ``task`` drags
        # behind it (the serving engine: the gang's live KV).  Without it
        # every migration is weightless and triple level-table entries
        # price exactly like their pair form.
        self.bytes_cb = None
        # consumer ruler for execution-side skew: ``speed_cb(component) ->
        # float`` is the relative decode speed of the host owning that
        # component (1.0 = nominal).  The costed steal survey weighs loot
        # by how slowly its current owner would drain it, and the LPT deal
        # divides a destination's load by its speed — so work drains
        # *away* from slow hosts, not merely away from full ones.  Without
        # the callback every component runs at 1.0 and both paths are the
        # historical ones, bit for bit.
        self.speed_cb = None
        # how a rebalance's level-table tolls are billed.  False (the
        # default): the triggering cpu pays the WHOLE bill through
        # consume_cost() — billed == accrued holds for every consumer,
        # tabled model or not (the PR 2 ledger property).  True (a
        # consumer that bills transfers where the data lands, e.g. the
        # serving engine's admission freezes): consume_cost() returns the
        # flat part only and the tolls are delivered via
        # ``stats.last_rebalance_ingest`` — the opting-in consumer MUST
        # bill them itself or they vanish from its stall ledger.
        self.ingest_billing = False
        self.stats = SchedStats()
        self.last_queue: Optional[RunQueue] = None   # lock-domain of last pick
        self.last_steal: Optional[tuple[RunQueue, Task]] = None  # (victim, loot)
        self._unbilled = 0.0       # cost accrued since the last consume_cost()

    def consume_cost(self) -> float:
        """Steal/rebalance penalty accrued since the last call, in quanta.

        The simulator bills this as a stall on the cpu whose scheduler call
        accrued it — that is how steal-happy policies *pay* for remote
        migrations instead of merely counting them."""
        c, self._unbilled = self._unbilled, 0.0
        return c

    def _bytes_of(self, task: Task) -> float:
        """Bytes a migration of ``task`` drags (0 without a consumer ruler)."""
        return self.bytes_cb(task) if self.bytes_cb is not None else 0.0

    def _speed_of(self, comp: Component) -> float:
        """Relative execution speed of the host owning ``comp`` (1.0 when
        no consumer ruler is installed, or for components above hosts)."""
        return self.speed_cb(comp) if self.speed_cb is not None else 1.0

    # -- application API (paper Figure 4) ------------------------------------
    def wake_up_bubble(self, b: Bubble, at: Optional[RunQueue] = None) -> None:
        # NOTE: explicit None test — RunQueue has __len__, so an *empty*
        # target queue is falsy and `at or global` would silently re-route
        # the wake-up to the global list.
        q = self.queues.global_queue() if at is None else at
        b.home_list = q
        q.push(b)

    def submit_thread(self, t: Thread) -> None:
        self.queues.global_queue().push(t)

    # -- burst-level decision --------------------------------------------------
    def _should_burst(self, b: Bubble, q: RunQueue, cpu: int) -> bool:
        if self.respect_hints and b.burst_level is not None:
            return q.level == b.burst_level or self._is_leaf(q)
        if self._is_leaf(q):
            return True
        # heuristic: burst once the bubble can no longer sink without
        # shrinking its scheduling area below its parallel width
        child = self._child_toward(q.comp, cpu)
        return child is None or b.total_width() > self._capacity(child)

    def _is_leaf(self, q: RunQueue) -> bool:
        return not q.comp.children

    @staticmethod
    def _capacity(comp: Component) -> int:
        return sum(1 for _ in comp.leaves())

    def _child_toward(self, comp: Component, cpu: int) -> Optional[Component]:
        """The child of ``comp`` on the path toward ``cpu``."""
        path = self.topo.cpus[cpu].path()
        try:
            i = path.index(comp)
        except ValueError:
            return None
        return path[i + 1] if i + 1 < len(path) else None

    # -- the scheduler entry point ----------------------------------------------
    def next_thread(self, cpu: int, now: float = 0.0,
                    allow_steal: bool = True,
                    task_filter=None) -> Optional[Thread]:
        """Called by an (idle or preempting) cpu.  Returns a runnable thread.

        While looking for threads, also "pulls down" bubbles from high list
        levels and makes them burst on a more local level (§4).
        ``task_filter`` makes ineligible tasks invisible to the lookup AND
        the steal survey — a consumer-side admission gate (the serving
        engine's weighted-deficit round-robin across SLA classes) that
        keeps the walk itself, and every unfiltered schedule, untouched.
        """
        for _ in range(64 * len(self.topo.levels)):       # progress bound
            found = self.queues.find(cpu, task_filter)
            if found is None:
                if allow_steal and self.steal:
                    stolen = self._steal_pass(cpu, task_filter)
                    if stolen is not None:
                        _, task = stolen
                        # re-home the stolen task near us and retry
                        self._place_near(task, cpu)
                        continue
                return None
            q, task = found
            self.last_queue = q
            if isinstance(task, Thread):
                self.stats.schedules += 1
                if task.last_cpu is not None and task.last_cpu != cpu:
                    self.stats.migrations += 1
                task.last_cpu = cpu
                return task
            b = task
            if b.done():
                continue
            if self._should_burst(b, q, cpu):
                self._burst(b, q, now)
            else:
                child = self._child_toward(q.comp, cpu)
                assert child is not None
                self.queues.queue_of(child).push(b)
                self.stats.sinks += 1
        return None

    def _burst(self, b: Bubble, q: RunQueue, now: float) -> None:
        b.burst = True
        b.home_list = q
        b.released_at = now
        for c in b.children:
            if isinstance(c, Thread) and c.remaining <= 0:
                continue
            q.push(c)
        self.stats.bursts += 1

    # -- hierarchical work stealing (§3.3.3) ----------------------------------
    def _steal_pass(self, cpu: int, task_filter=None
                    ) -> Optional[tuple[RunQueue, Task]]:
        """Steal a whole bubble, preferring the victim worth its price.

        Two victim-selection regimes, switched by the cost model:

        * **free stealing** (all per-steal penalties zero, the default):
          walk the covering levels local→global and take the heaviest loot
          from the *closest* level that has any.  At each ancestor of
          ``cpu`` (nearest first) every sibling subtree is inspected; a
          closed bubble is preferred over any lone thread at the same
          level — moving the whole group keeps its internal affinity
          intact; among candidates of the same kind the one with the most
          remaining work wins (steal enough to stay busy), with sibling
          closeness breaking exact work ties via scan order.  Only when a
          level offers nothing does the walk widen to the next level out.
        * **costed stealing** (any nonzero per-steal penalty): distance is
          no longer a hard tier but a price, so *all* covering levels are
          surveyed and candidates are ranked by **work-per-cost**
          (``remaining_work / steal_cost(levels_crossed, live_threads)``)
          — a nearer, slightly lighter bubble beats a heavier one that
          would drag more threads across more levels.  Bubbles still beat
          lone threads (the affinity argument is price-independent), and
          the local→global scan order still breaks exact score ties toward
          the nearest victim.

        On success the loot is *removed from the victim queue* (identity-
        safe), counted in :class:`SchedStats` (including the per-distance
        histogram), its threads flagged ``stolen`` for the next-touch
        memory policy, and ``(victim_queue, task)`` is returned — the
        caller re-places the task near the thief.
        """
        self.stats.steal_attempts += 1
        path = self.topo.cpus[cpu].path()                 # root → leaf
        if not self.cost_model.steals_are_free:
            return self._steal_pass_costed(cpu, path, task_filter)
        for depth in range(len(path) - 2, -1, -1):        # local → global
            anc, mine = path[depth], path[depth + 1]
            best_bubble = best_thread = None              # (queue, task, work)
            siblings = sorted((c for c in anc.children if c is not mine),
                              key=lambda c: abs(c.index - mine.index))
            for sib in siblings:
                for comp in self._bfs(sib):
                    q = self.queues.queue_of(comp)
                    for t in q.tasks:
                        if task_filter is not None and not task_filter(t):
                            continue
                        if isinstance(t, Bubble):
                            if t.done():
                                continue
                            if not self._accepts(cpu, t):
                                continue
                            w = t.total_work()
                            if best_bubble is None or w > best_bubble[2]:
                                best_bubble = (q, t, w)
                        elif t.remaining > 0:
                            if not self._accepts(cpu, t):
                                continue
                            if best_thread is None or t.remaining > best_thread[2]:
                                best_thread = (q, t, t.remaining)
            best = best_bubble or best_thread
            if best is None:
                continue
            victim, task, work = best
            return self._commit_steal(cpu, victim, task, work)
        return None

    @staticmethod
    def _steal_score(work: float, cost: float) -> float:
        """Work-per-cost, with free loot scoring infinitely well: a model
        whose only nonzero penalty lives in the level table leaves
        un-tabled boundaries at cost 0, and dividing by it would crash the
        survey.  Ties among free candidates resolve by scan order — the
        most local one wins, as everywhere else."""
        return work / cost if cost > 0 else float("inf")

    def _steal_pass_costed(self, cpu: int, path: list[Component],
                           task_filter=None
                           ) -> Optional[tuple[RunQueue, Task]]:
        """Cost-aware victim selection: survey every covering level and
        maximise work-per-cost (ROADMAP follow-up to the PR 2 cost model).

        The level walk shares the free path's scan order (ancestors nearest
        first, siblings by closeness, BFS within a subtree), so exact-score
        ties still resolve toward the most local victim."""
        best_bubble = best_thread = None      # (score, queue, task, work)
        tspeed = self._speed_of(self.topo.cpus[cpu])
        for depth in range(len(path) - 2, -1, -1):        # local → global
            anc, mine = path[depth], path[depth + 1]
            siblings = sorted((c for c in anc.children if c is not mine),
                              key=lambda c: abs(c.index - mine.index))
            for sib in siblings:
                for comp in self._bfs(sib):
                    q = self.queues.queue_of(comp)
                    if not q.tasks:
                        continue
                    dist = self.topo.levels_crossed(cpu, comp)
                    boundary = self.topo.crossing_level(cpu, comp)
                    # loot sitting under a slow host drains slowly where it
                    # is — its *effective* backlog (work / victim speed) is
                    # larger, so the survey prefers rescuing it.  Uniform
                    # speed (no speed_cb) divides everything by 1.0.
                    vspeed = self._speed_of(comp)
                    if vspeed > tspeed + 1e-9:
                        # work only drains toward equal-or-faster hosts: a
                        # straggler pulling loot off a faster victim would
                        # turn that work into its own longest-running tail
                        # (the victim's slots finish it sooner than the
                        # thief ever could).  Uniform speed skips nothing.
                        continue
                    for t in q.tasks:
                        if task_filter is not None and not task_filter(t):
                            continue
                        if isinstance(t, Bubble):
                            if t.done():
                                continue
                            if not self._accepts(cpu, t):
                                continue
                            w = t.total_work()
                            n = sum(1 for th in t.threads()
                                    if th.remaining > 0)
                            score = self._steal_score(
                                w / vspeed, self.cost_model.steal_cost(
                                    dist, n, boundary, self._bytes_of(t)))
                            if best_bubble is None or score > best_bubble[0]:
                                best_bubble = (score, q, t, w)
                        elif t.remaining > 0:
                            if not self._accepts(cpu, t):
                                continue
                            score = self._steal_score(
                                t.remaining / vspeed,
                                self.cost_model.steal_cost(
                                    dist, 1, boundary, self._bytes_of(t)))
                            if best_thread is None or score > best_thread[0]:
                                best_thread = (score, q, t, t.remaining)
        best = best_bubble or best_thread
        if best is None:
            return None
        _, victim, task, work = best
        return self._commit_steal(cpu, victim, task, work)

    def _accepts(self, cpu: int, task: Task) -> bool:
        """Capacity veto for one steal candidate: the consumer's callback
        decides whether the thief's area can hold the loot.  Refusals are
        accounted — a high refusal count with idle cpus means the machine
        is capacity-bound, not work-bound."""
        if self.capacity_cb is None or self.capacity_cb(cpu, task, ()):
            return True
        self.stats.steal_refusals += 1
        return False

    def _commit_steal(self, cpu: int, victim: RunQueue, task: Task,
                      work: float) -> tuple[RunQueue, Task]:
        """Book one successful steal: remove the loot (identity-safe), flag
        its threads for next-touch, and settle the cost ledger (billed at
        ``bill_model`` prices — the machine's, not the scheduler's)."""
        victim.remove(task)
        self.stats.steals += 1
        self.stats.stolen_work += work
        if isinstance(task, Bubble):
            self.stats.bubble_steals += 1
            n_moved = 0
            for th in task.threads():
                th.stolen = True
                if th.remaining > 0:
                    n_moved += 1
        else:
            self.stats.thread_steals += 1
            task.stolen = True
            n_moved = 1
        dist = self.topo.levels_crossed(cpu, victim.comp)
        cost = self.bill_model.steal_cost(
            dist, n_moved, self.topo.crossing_level(cpu, victim.comp),
            self._bytes_of(task))
        self.stats.stolen_threads += n_moved
        self.stats.steal_distance += dist
        self.stats.steal_distance_hist[dist] = \
            self.stats.steal_distance_hist.get(dist, 0) + 1
        self.stats.steal_cost += cost
        self.stats.last_steal_distance = dist
        self.stats.last_steal_cost = cost
        self._unbilled += cost
        self.last_steal = (victim, task)
        return victim, task

    # -- proactive rebalancing (ARMS-style re-mapping, arXiv:2112.09509) ------
    def _resolve_spread_level(self, level: Optional[str]) -> str:
        """The level a ``level=None`` rebalance re-spreads across.

        Derived from the observed steal-distance histogram rather than a
        fixed knob: the modal distance names how far work is actually being
        dragged, and the matching re-spread deals across the components
        just below the deepest ancestor those steals crossed — cross-node
        steal traffic (distance 2 on the NovaScale) re-spreads across
        ``node`` lists, sibling-cpu churn (distance 1) across the per-cpu
        lists.  Ties prefer the longer distance (re-spreading wider only
        widens scheduling freedom).  Before any steal has been observed the
        historical default applies: the level just above the leaves."""
        if level is not None:
            return level
        hist = self.stats.steal_distance_hist
        if hist:
            d = max(hist, key=lambda k: (hist[k], k))
            idx = min(max(len(self.topo.levels) - d, 1),
                      len(self.topo.levels) - 1)
            return self.topo.levels[idx].name
        return self.topo.levels[max(0, len(self.topo.levels) - 2)].name

    def _resolve_scope(self, scope) -> Optional[Component]:
        """``scope`` as a :class:`Component`: accepts a component object, a
        component name (``"host1"``), or ``None`` (the whole machine)."""
        if scope is None or isinstance(scope, Component):
            return scope
        return self.topo.component(scope)

    def _gatherable(self, scope: Optional[Component] = None):
        """(queue, task) for every task a rebalance would move: runnable
        threads and closed non-empty bubbles on any list (burst husks stay
        put for regeneration).  With ``scope`` set, only lists *inside*
        that subtree are gathered — a host-local re-spread never touches
        another host's backlog, or the lists covering the scope from
        above (their work is already reachable by the whole scope)."""
        for q in self.queues.queues.values():
            if scope is not None and scope not in q.comp.path():
                continue
            for t in list(q.tasks):
                if isinstance(t, Bubble):
                    if t.burst or t.done():
                        continue
                elif t.remaining <= 0:
                    continue
                yield q, t

    @staticmethod
    def _expand_unit(t: Task, cap: int):
        """Split units too wide for one target component (hierarchical
        placement): recurse into the bubble's children until each piece
        fits."""
        if isinstance(t, Bubble) and t.total_width() > cap:
            for c in t.children:
                if isinstance(c, Bubble):
                    if not c.done():
                        yield from BubbleScheduler._expand_unit(c, cap)
                elif c.remaining > 0:
                    yield c
        else:
            yield t

    def _spread_comps(self, level: Optional[str],
                      scope: Optional[Component]) -> list[Component]:
        """Target components a ``rebalance(level=, scope=)`` deals across:
        the resolved spread level's components, restricted to ``scope``'s
        subtree when one is given (a host-local re-spread deals across
        that host's page groups only)."""
        comps = self.topo.components(self._resolve_spread_level(level))
        if scope is not None:
            comps = [c for c in comps if scope in c.path()]
        assert comps, (level, scope and scope.name)
        return comps

    def queued_movable(self, level: Optional[str] = None,
                       scope=None) -> int:
        """Units a :meth:`rebalance` across ``level`` would re-place right
        now — counted *after* over-wide bubbles are expanded, so it equals
        the ``moves`` the rebalance would bill.  The adaptive policy's
        cost-benefit test uses this both as its backlog gate (an
        end-of-cycle steal-attempt spike over drained queues cannot
        trigger a rebalance that moves nothing but still bills its base
        cost) and to price the prospective re-spread accurately.  With
        ``scope`` set only that subtree's backlog counts (the host-local
        mode's gate)."""
        scope = self._resolve_scope(scope)
        cap = self._capacity(self._spread_comps(level, scope)[0])
        return sum(1 for _, t in self._gatherable(scope)
                   for _ in self._expand_unit(t, cap))

    def estimate_rebalance(self, level: Optional[str] = None,
                           scope=None) -> tuple[int, float]:
        """``(movable_units, prospective_cost)`` of a
        :meth:`rebalance(level=, scope=)` — the *quote*.

        The quote is exact, not a heuristic: it replays the very same
        gather → expand → LPT deal the rebalance would run (without
        touching any queue) and prices every resulting move by the
        boundary it crosses, at ``cost_model`` (the scheduler's *belief*)
        prices.  Anything cheaper would lie: on a pod-sharded fleet a
        machine-wide deal *will* send units across ``host``/``pod``
        boundaries, and a per-unit "cheapest destination" bound prices
        every unit at its own page — flat — hiding exactly the DCN tolls
        the mode exists to surface.

        This is how a DCN-priced trigger compares modes: the machine-wide
        quote carries its unavoidable tolls, a host-local ``scope`` quotes
        flat page shuffles only, and the trigger buys the cheaper fix.  On
        a table-free (or single-host) topology every boundary prices to
        the flat per-move cost and the quote degenerates to exactly
        ``cost_model.rebalance_cost(queued_movable(...))``, so flat
        consumers see bit-identical trigger decisions."""
        scope = self._resolve_scope(scope)
        comps = self._spread_comps(level, scope)
        cap = self._capacity(comps[0])
        units = [(q.comp, u) for q, t in self._gatherable(scope)
                 for u in self._expand_unit(t, cap)]
        _, cost, _, _ = self._lpt_deal(units, comps, self.cost_model)
        return len(units), cost

    @staticmethod
    def _unit_weight(t: Task) -> float:
        return t.total_work() if isinstance(t, Bubble) else t.remaining

    def _lpt_deal(self, units: list[tuple[Component, Task]],
                  comps: list[Component], model: StealCostModel
                  ) -> tuple[list[tuple[Task, Component]], float, int,
                             dict[str, float]]:
        """The deal itself, shared by :meth:`rebalance` (which commits it)
        and :meth:`estimate_rebalance` (which only wants the bill): assign
        ``(source_component, unit)`` pairs across ``comps``
        longest-processing-time-first, respecting ``capacity_cb`` — the
        least-loaded component that can hold the unit *on top of what this
        deal already routed there* wins (the consumer's ledger only
        reserves at claim time, so without the pending list one deal could
        overcommit a destination that had room for a single unit); a unit
        nothing accepts falls back to the global list, where every cpu can
        reach it and admission paces it in as capacity frees.

        Touches no queue and no ledger.  Returns ``(assignments, cost,
        refused, ingest)``: the ``(unit, destination)`` list in deal
        order; the total bill at ``model`` prices — ``rebalance_base``
        plus each move's boundary-priced
        :meth:`StealCostModel.rebalance_move_cost` for the source-list →
        destination crossing (the global-list fallback crosses nothing);
        the refused-unit count; and ``ingest``, the destination-side split
        of the bill's level-table extras (component name → summed tolls of
        the moves dealt into it) for consumers that bill transfers where
        the data lands.  The sort is stable, so exact-weight ties keep
        gather order (goldens depend on it)."""
        units = sorted(units, key=lambda su: self._unit_weight(su[1]),
                       reverse=True)
        loads = [0.0] * len(comps)
        # heterogeneous-speed LPT: a destination's effective completion
        # time is its dealt load divided by its host's speed, so slow
        # hosts fill up "sooner" and receive proportionally less work.
        # Uniform speeds (no speed_cb) divide by 1.0 and reproduce the
        # historical deal — same argmin, same stable ties.
        speeds = [self._speed_of(c) for c in comps]
        placed: list[list[Task]] = [[] for _ in comps]
        assignments: list[tuple[Task, Component]] = []
        ingest: dict[str, float] = {}
        refused = 0
        cost = model.rebalance_base

        def comp_accepts(i: int, u: Task) -> bool:
            # the callback answers for the area around one cpu; a target
            # component above that granularity (a host spanning several
            # page groups) accepts when *any* of its sub-areas does —
            # admission remains the true guard once the unit is claimed
            if self.capacity_cb is None:
                return True
            pending = tuple(placed[i])
            return any(self.capacity_cb(leaf.cpu, u, pending)
                       for leaf in comps[i].leaves())

        for src, u in units:
            fits = [i for i in range(len(comps)) if comp_accepts(i, u)]
            w = self._unit_weight(u)
            if not fits:
                refused += 1
                comp = self.topo.root
            else:
                i = min(fits, key=lambda j: (loads[j] + w) / speeds[j])
                comp = comps[i]
                loads[i] += w
                placed[i].append(u)
            move = model.rebalance_move_cost(
                self.topo.crossing_between(src, comp), self._bytes_of(u))
            cost += move
            extra = move - model.rebalance_per_move
            if extra > 0:
                ingest[comp.name] = ingest.get(comp.name, 0.0) + extra
            assignments.append((u, comp))
        return assignments, cost, refused, ingest

    def rebalance(self, cpu: int, now: float = 0.0,
                  level: Optional[str] = None, scope=None) -> int:
        """Re-gather every queued task and re-spread the lot hierarchically.

        Serial stealing drains an overloaded list one migration at a time,
        paying the remote lock/latency cost per steal; when steal traffic
        spikes it is cheaper to re-place the whole backlog at once.  This
        gathers all runnable tasks off every list (closed bubbles move as
        whole affinity groups; burst bubbles' scattered threads move
        individually — their husks stay put for regeneration) and deals
        them across the components of ``level`` (default: the level just
        above the leaves, e.g. NUMA nodes) longest-processing-time-first,
        so each component's list receives a near-equal share of remaining
        work and subsequent lookups succeed locally instead of stealing.

        ``scope`` (a :class:`~repro.core.topology.Component` or its name,
        e.g. ``"host1"``) is the **host-local mode**: both the gather and
        the deal are restricted to that subtree, so the re-spread fixes
        skew *inside* one machine region without quoting — or paying —
        any boundary outside it.  On a DCN-priced fleet that is the
        difference between a free page shuffle and a bill of per-move
        ``host``/``pod`` tolls; :meth:`estimate_rebalance` is how a
        trigger compares the two before committing.

        Placement is *hierarchical*: a gathered bubble wider than one
        component of the target level cannot fit anywhere and would flood
        whichever list received it, so it is expanded into its children
        (recursively, until each unit fits) and the pieces are dealt out
        individually — balance bought by giving up that bubble's top-level
        affinity grouping, the paper's affinity/balance trade made
        explicit.  Bubbles that fit stay whole.

        Threads landing outside the subtree of their last cpu are flagged
        ``stolen`` so the next-touch data policy re-homes their pages, the
        same as a steal would.  When a ``capacity_cb`` is installed the
        deal only targets components that can hold each unit (a full KV
        page group refuses loot here exactly as it does in the steal
        survey); units nothing accepts fall back to the global list.
        Returns the number of tasks re-placed; the triggering cpu is
        billed ``bill_model.rebalance_base`` plus, per move, the
        boundary-priced :meth:`StealCostModel.rebalance_move_cost` for the
        crossing between the unit's source list and its destination —
        flat topologies (no ``level_table``) bill exactly the historical
        ``rebalance_cost(moves)``.
        """
        scope = self._resolve_scope(scope)
        comps = self._spread_comps(level, scope)
        cap = self._capacity(comps[0])
        gathered: list[tuple[Component, Task]] = []
        for q, t in self._gatherable(scope):
            q.remove(t)
            gathered.append((q.comp, t))
        units = [(src, u) for src, t in gathered
                 for u in self._expand_unit(t, cap)]
        assignments, cost, refused, ingest = self._lpt_deal(units, comps,
                                                            self.bill_model)
        self.stats.steal_refusals += refused
        for u, comp in assignments:
            self.queues.queue_of(comp).push(u)
            threads = u.threads() if isinstance(u, Bubble) else (u,)
            for th in threads:
                if (th.last_cpu is not None
                        and comp not in self.topo.cpus[th.last_cpu].path()):
                    th.stolen = True          # next-touch re-homes its data
        moves = len(units)
        self.stats.rebalances += 1
        self.stats.rebalance_moves += moves
        self.stats.rebalance_cost += cost
        self.stats.last_rebalance_moves = moves
        self.stats.last_rebalance_cost = cost
        self.stats.last_rebalance_ingest = ingest
        # Under ``ingest_billing`` the bill is split: the triggering cpu
        # pays the flat descriptor sweep (base + per-move) through
        # consume_cost(), as it always has, and the level-table tolls are
        # *transfer* costs the consumer bills where the data lands
        # (``last_rebalance_ingest``).  Without it the trigger cpu pays
        # everything — billed == accrued for consumers (the simulator)
        # that never read the ingest side.  Table-free models: ingest is
        # empty and both paths are the historical ledger, bit for bit.
        self._unbilled += self.bill_model.rebalance_cost(moves) \
            if self.ingest_billing else cost
        return moves

    @staticmethod
    def _bfs(comp: Component):
        """Breadth-first components of a subtree — shallowest queues first,
        so the widest (most shareable) lists of a victim are tried before
        its per-cpu ones."""
        frontier = [comp]
        while frontier:
            nxt: list[Component] = []
            for c in frontier:
                yield c
                nxt.extend(c.children)
            frontier = nxt

    def _place_near(self, task: Task, cpu: int) -> None:
        """Place a stolen task on the closest list that can hold it."""
        chain = self.queues.covering(cpu)                 # local → global
        if isinstance(task, Bubble):
            width = task.total_width()
            for q in chain:
                if self._capacity(q.comp) >= width or q is chain[-1]:
                    q.push(task, front=True)
                    return
        chain[0].push(task, front=True)

    # -- regeneration (§3.3.3) ---------------------------------------------------
    def regenerate(self, b: Bubble, running: dict[int, Thread]) -> None:
        """Close a burst bubble: pull its tasks off all queues, push the
        closed bubble back at the end of its home list.

        Threads currently being executed "go back in the bubble by
        themselves" — the simulator calls :meth:`thread_returned` when a
        running thread next yields.
        """
        if not b.burst:
            return
        live = set(id(t) for t in running.values())
        for sub in b.bubbles():
            for q in self.queues.queues.values():
                for t in list(q.tasks):
                    if t.parent is sub and id(t) not in live:
                        q.remove(t)
            sub.burst = False
        self.stats.regenerations += 1
        home = (self.queues.global_queue() if b.home_list is None
                else b.home_list)       # empty home queues are falsy!
        b.waiting_running = [t for t in b.threads()
                             if id(t) in live and t.remaining > 0]
        if not b.waiting_running:
            home.push(b)
        else:
            b.pending_home = home

    def thread_returned(self, t: Thread) -> None:
        """A running thread yielded after its bubble was regenerated."""
        b = t.parent
        while b is not None:
            wr = getattr(b, "waiting_running", None)
            if wr and t in wr:
                wr.remove(t)
                if not wr:
                    getattr(b, "pending_home").push(b)
            b = b.parent
