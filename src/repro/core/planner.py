"""Static bubble scheduling: bubble tree → mesh placement plan.

This is the paper's mechanism applied at *compile* time to a TPU mesh.  The
model definition emits a bubble tree whose leaves are **logical dimensions**
of the computation (batch, heads, d_ff, experts, vocab, seq, ...), each with
a parallel *width* (how many ways it can be split) and whose nesting encodes
affinity (everything inside one layer bubble wants to live close together;
the batch bubble is independent of parameter bubbles).

The machine side is the mesh-axis hierarchy, outer→inner — on the production
meshes ``("pod","data","model")``: crossing ``pod`` is DCN (most expensive),
crossing ``data`` is long ICI routes, ``model`` is the tight neighborhood.

The planner plays the scheduler's game statically:

* a bubble **sinks** below an axis when sharding its contents across that
  axis would break the affinity it expresses (its tensors would be spread
  over the expensive boundary) or when its width cannot fill the axis;
* a bubble **bursts** at an axis when its width fills it, releasing its
  children; the axis is consumed by sharding the bubble's released dims.

The output is a :class:`Plan` mapping logical dims → mesh axes, the exact
analogue of "which list does each task end up on".  ``distributed.sharding``
turns plans + per-tensor logical-dim annotations into PartitionSpecs.

The paper's Table-2 strategies map to plan *sources*:

* ``simple``  — opportunist: everything data-parallel (batch over all axes);
* ``bound``   — a hand-written per-arch axis table (non-portable);
* ``bubbles`` — derived from the model's bubble tree by this planner.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from .bubble import Bubble, Task


@dataclass
class Dim(Task):
    """A leaf of the planner tree: one logical dimension of the computation.

    ``width``  — the extent that can be split (e.g. n_kv_heads, n_experts,
                 global_batch).
    ``min_level`` — outermost axis this dim may be sharded on (affinity
                 ceiling): batch tolerates ``pod``; parameter dims usually
                 set ``min_level="model"`` so their collectives stay on the
                 tight neighborhood.
    ``weight`` — relative communication volume of sharding this dim; used to
                 break ties when several dims compete for one axis.
    """

    width: int = 1
    min_level: Optional[str] = None
    weight: float = 1.0
    # activation dims (batch, seq) co-occur with every parameter dim in the
    # layer activations, so the planner never lets them share a mesh axis
    # with a parameter dim (and vice versa)
    is_activation: bool = False


@dataclass(frozen=True)
class MeshAxis:
    name: str
    size: int


@dataclass
class Plan:
    """dim name → tuple of mesh axis names (possibly empty = replicated)."""

    assignment: dict[str, tuple[str, ...]] = field(default_factory=dict)
    log: list[str] = field(default_factory=list)
    strategy: str = "bubbles"

    def axes_of(self, dim: Optional[str]) -> Optional[tuple[str, ...]]:
        if dim is None:
            return None
        return self.assignment.get(dim) or None

    def pretty(self) -> str:
        rows = [f"  {d:12s} -> {ax or '(replicated)'}"
                for d, ax in sorted(self.assignment.items())]
        return f"Plan[{self.strategy}]\n" + "\n".join(rows)


def _level_order(axes: Sequence[MeshAxis]) -> dict[str, int]:
    return {a.name: i for i, a in enumerate(axes)}


def plan_bubbles(root: Bubble, axes: Sequence[MeshAxis]) -> Plan:
    """Run static bubble scheduling over the mesh-axis hierarchy.

    Walk the axes outer→inner.  Dims under the same immediate bubble share
    tensors, so they *compete* for each axis (one dim of a tensor per mesh
    axis); dims under sibling bubbles execute as separate operations and may
    share an axis freely — exactly the bubble-as-affinity-scope semantics.
    Among competitors whose ``min_level`` permits the axis and whose
    remaining width divides it, the heaviest (then widest) dim wins.  A dim
    may win several consecutive axes (batch over ``("pod","data")``) while
    its width keeps dividing.
    """
    plan = Plan(strategy="bubbles")
    order = _level_order(axes)

    # collect dims with their affinity ceilings and competition groups; a
    # Dim nested under a bubble with burst_level=L inherits L as its
    # min_level unless it sets its own.
    dims: list[Dim] = []
    group_of: dict[int, int] = {}       # dim tid -> id of immediate bubble

    def collect(node: Task, inherited: Optional[str], parent_id: int) -> None:
        if isinstance(node, Dim):
            node._eff_level = node.min_level or inherited  # type: ignore
            dims.append(node)
            group_of[node.tid] = parent_id
        elif isinstance(node, Bubble):
            nxt = node.burst_level or inherited
            for c in node.children:
                collect(c, nxt, node.tid)

    collect(root, None, -1)
    for d in dims:
        plan.assignment.setdefault(d.name, tuple())

    remaining = {d.tid: d.width for d in dims}
    claimed: dict[tuple[int, str], str] = {}   # (group, axis) -> dim name
    act_axes: set[str] = set()                 # axes won by activation dims
    param_axes: set[str] = set()               # axes won by parameter dims
    for ax in axes:
        by_group: dict[int, list[Dim]] = {}
        for d in dims:
            lvl = getattr(d, "_eff_level", None)
            if lvl is not None and order.get(lvl, len(axes)) > order[ax.name]:
                continue                 # must sink below this axis
            if remaining[d.tid] % ax.size != 0 or remaining[d.tid] < ax.size:
                continue
            # activation/parameter exclusivity (both kinds share the layer
            # activation tensors)
            if d.is_activation and ax.name in param_axes:
                continue
            if not d.is_activation and ax.name in act_axes:
                continue
            by_group.setdefault(group_of[d.tid], []).append(d)
        if not by_group:
            plan.log.append(f"axis {ax.name}(x{ax.size}): unfilled")
            continue
        # heaviest groups first, so contested axes go to the dims that
        # benefit most (params on the inner axis beat batch spillover)
        ordered = sorted(by_group.items(),
                         key=lambda kv: -max(d.weight for d in kv[1]))
        for grp, cands in ordered:
            if (grp, ax.name) in claimed:
                continue
            cands = [d for d in cands
                     if (ax.name not in param_axes if d.is_activation
                         else ax.name not in act_axes)]
            if not cands:
                continue
            win = max(cands, key=lambda d: (d.weight, remaining[d.tid]))
            claimed[(grp, ax.name)] = win.name
            (act_axes if win.is_activation else param_axes).add(ax.name)
            plan.assignment[win.name] += (ax.name,)
            remaining[win.tid] //= ax.size
            plan.log.append(
                f"axis {ax.name}(x{ax.size}): burst '{win.name}' "
                f"(remaining width {remaining[win.tid]})")
    return plan


def plan_simple(batch_dim: str, axes: Sequence[MeshAxis]) -> Plan:
    """Opportunist baseline: pure data parallelism, parameters replicated."""
    p = Plan(strategy="simple")
    p.assignment[batch_dim] = tuple(a.name for a in axes)
    p.log.append(f"pure DP: {batch_dim} over {p.assignment[batch_dim]}")
    return p


def plan_bound(table: dict[str, tuple[str, ...]]) -> Plan:
    """Predetermined baseline: a hand-written axis table (non-portable)."""
    p = Plan(strategy="bound", assignment=dict(table))
    p.log.append("hand-written table")
    return p
