"""Discrete-event simulator of a hierarchical machine (paper reproduction).

Reproduces the paper's evaluation setting on CPU, with the NUMA factor as the
only hardware parameter: a thread progressing on cpu *c* while its data is
homed under another component of level *L* advances at ``1/L.factor`` speed
(the paper's NovaScale: "accessing the memory of another node is about 3
times slower", §5.2).

Data homing supports the two §2.3 policies:

* **first touch** (the default Linux/Solaris policy): the first cpu to run a
  thread homes that thread's data at its own position; migrating the thread
  later does *not* migrate the data;
* **next touch** (``data_policy="next_touch"``): a thread that was *stolen*
  (``Thread.stolen``, set by the scheduler's steal pass) re-homes its data at
  the next cpu that touches it, so migrated work stops paying the remote
  NUMA factor after one quantum.  ``migration_cost`` charges the moving
  touch (page-migration latency, in extra slowdown for that quantum).
  :class:`~repro.core.policies.StealPolicy` selects this policy via its
  ``preferred_data_policy`` attribute; an explicit ``data_policy=`` argument
  always wins.

The simulator advances in fixed quanta; each busy cpu runs its thread for one
quantum per tick (all speeds relative).  Workloads with barrier cycles
(conduction/advection) re-arm all threads at each barrier, which is also each
policy's rebalancing opportunity — exactly the structure of the paper's
"cycles of fully parallel computing followed by global communication barrier".

The scheduling-decision loop itself (lookup, steal billing, data homing, the
cost ledger) lives in :class:`~repro.core.runtime.SchedulerRuntime`; the
simulator is one thin client of it — the serving engine is the other — and
only owns what is genuinely simulation: the clock, the speed model, and the
contention/stall accounting.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .bubble import Bubble, Thread, bubble, thread
from .policies import Policy, _h
from .runtime import SchedulerRuntime
from .scheduler import StealCostModel
from .topology import Topology


@dataclass
class SimResult:
    policy: str
    time: float                  # simulated time units
    busy: float                  # total busy cpu-time
    ideal: float                 # total work (= busy time at speed 1)
    migrations: int
    lookup_steps: float          # mean scan steps per scheduler call
    cycles: int = 1
    data_migrations: int = 0     # next-touch page migrations performed
    extra: dict = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        """vs a single cpu running all work locally."""
        return self.ideal / self.time if self.time else float("inf")

    @property
    def efficiency(self) -> float:
        return self.speedup / self.extra.get("n_cpus", 1)


class Simulator:
    def __init__(self, topo: Topology, policy: Policy, *,
                 quantum: float = 1.0, jitter: float = 0.0,
                 mem_fraction: float = 1.0, contention: float = 0.0,
                 data_policy: Optional[str] = None,
                 migration_cost: float = 0.0):
        self.topo = topo
        self.policy = policy
        self.quantum = quantum
        self.jitter = jitter            # per-(thread,cycle) work heterogeneity
        self.mem_fraction = mem_fraction  # share of time that is memory-bound
        # lock contention: extra stall (in quanta) per *earlier* picker from
        # the same lock domain within one tick — the paper's "unique thread
        # list for the whole machine is a bottleneck" (§2.2).
        self.contention = contention
        # the shared decision loop: data-policy resolution (explicit arg >
        # policy preference > first touch), homes map, migration log
        self.runtime = SchedulerRuntime(topo, policy, data_policy=data_policy)
        self.migration_cost = migration_cost

    # the runtime owns the data-homing state; these delegations keep the
    # simulator's historical surface (tests/benchmarks read them directly)
    @property
    def data_policy(self) -> str:
        return self.runtime.data_policy

    @property
    def homes(self) -> dict[str, int]:
        return self.runtime.homes

    @property
    def data_migrations(self) -> int:
        return self.runtime.data_migrations

    @property
    def migration_log(self) -> list[tuple[str, int, int]]:
        return self.runtime.migration_log

    # -- speed model ---------------------------------------------------------
    def _speed(self, cpu: int, t: Thread) -> float:
        """Remote data slows only the memory-bound fraction of the work:
        slowdown = 1 + mem_fraction * (factor - 1).  mem_fraction=1.0 is a
        pure memory-latency-bound thread; the paper's stencil codes sit
        around 0.25 (calibrated so *simple* lands at the paper's 10.58).

        The data-policy decision (first/next touch, §2.3) is the runtime's;
        the simulator only prices the outcome: a migrating touch pays the
        page-copy latency for one quantum, every other touch pays the NUMA
        distance to wherever the data is homed."""
        home, migrated = self.runtime.touch(cpu, t)
        if migrated and self.migration_cost:
            return 1.0 / (1.0 + self.migration_cost)
        f = self.topo.distance_factor(cpu, home)
        return 1.0 / (1.0 + self.mem_fraction * (f - 1.0))

    # -- one barrier-delimited cycle ------------------------------------------
    def run_cycle(self, root: Bubble, now: float, cycle: int) -> float:
        """Run until every thread of ``root`` has remaining<=0.  Returns the
        elapsed time (the cycle makespan)."""
        threads = list(root.threads())
        pending = sum(1 for t in threads if t.remaining > 0)
        running: list[Optional[Thread]] = [None] * self.topo.n_cpus
        stall = [0.0] * self.topo.n_cpus
        t0 = now
        guard = 0
        while pending > 0:
            guard += 1
            assert guard < 10_000_000, "simulator wedged"
            idle = True
            tick_picks: dict = {}
            for cpu in range(self.topo.n_cpus):
                if stall[cpu] > 0:                  # lock-contention stall
                    stall[cpu] -= 1.0
                    idle = False
                    continue
                cur = running[cpu]
                if cur is None:
                    # one runtime acquire = policy lookup + the steal/
                    # rebalance penalty that call accrued (StealCostModel):
                    # the *thief* stalls for the remote lock/latency it
                    # caused — migration decisions have a cost side, not
                    # just a counter.  Applied on top of (never clobbered
                    # by) the lock-contention stall below.
                    cur, cost = self.runtime.acquire(cpu, now)
                    if cur is None:
                        if cost:
                            stall[cpu] += cost
                            idle = False
                        continue
                    if cur.remaining <= 0:          # stale entry: drop
                        self.runtime.release(cpu, cur, True, now)
                        continue
                    running[cpu] = cur
                    if self.contention:
                        dom = self.policy.last_domain
                        prev = tick_picks.get(dom, 0)
                        tick_picks[dom] = prev + 1
                        stall[cpu] = self.contention * prev
                    if cost:
                        stall[cpu] += cost
                idle = False
                cur.remaining -= self.quantum * self._speed(cpu, cur)
                if cur.remaining <= 0:
                    cur.remaining = 0.0
                    running[cpu] = None
                    self.runtime.release(cpu, cur, True, now)
                    pending -= 1
            now += self.quantum
            if idle and pending > 0:
                # nothing runnable anywhere — should not happen with work
                # conserving policies; advance time to avoid livelock.
                now += self.quantum
        return now - t0

    # -- full workload ---------------------------------------------------------
    def run(self, root: Bubble, cycles: int = 1) -> SimResult:
        ideal = 0.0
        for t in root.threads():
            ideal += t.work * cycles
        self.policy.submit(root)
        now, total = 0.0, 0.0
        mig0 = self.runtime.sched_migrations()
        dmig0 = self.data_migrations
        c0 = self.runtime.counters()
        for cyc in range(cycles):
            if cyc > 0:
                for t in root.threads():
                    w = t.work
                    if self.jitter:
                        w *= 1.0 + self.jitter * (_h(t.tid, cyc) - 0.5)
                    t.remaining = w
                self.runtime.barrier(root, now)
            elapsed = self.run_cycle(root, now, cyc)
            total += elapsed
            now += elapsed
        steps, lookups = self.policy.lookup_cost()
        return SimResult(
            policy=self.policy.name, time=total, busy=total, ideal=ideal,
            migrations=self.runtime.sched_migrations() - mig0,
            lookup_steps=steps / lookups, cycles=cycles,
            data_migrations=self.data_migrations - dmig0,
            extra={"n_cpus": self.topo.n_cpus, "homes": dict(self.homes),
                   "data_policy": self.data_policy,
                   **self.runtime.counter_deltas(c0, self.runtime.counters())},
        )


# ---------------------------------------------------------------------------
# the paper's workloads
# ---------------------------------------------------------------------------

def stripes_workload(n_threads: int, work: float = 100.0,
                     group: Optional[int] = None,
                     skew: float = 0.0,
                     groups: Optional[list[int]] = None,
                     burst_level: Optional[str] = None) -> Bubble:
    """Conduction/advection (§5.2): mesh split into stripes, one thread per
    stripe, cycles of parallel compute + barrier.  ``group`` = threads per
    bubble; ``None`` = flat (the *simple*/*bound* versions).

    Two imbalance knobs build the work-stealing stress cases:

    * ``skew`` makes the stripe *work* uneven (an irregular mesh): stripe
      ``i`` carries ``work * (1 + skew * i / (n_threads - 1))``, so
      ``skew=1.0`` gives the last stripe twice the work of the first;
    * ``groups`` makes the bubble *tree* uneven — an explicit list of
      per-group thread counts (overrides ``group``/``n_threads``), e.g.
      ``groups=[2, 2, 4, 4, 8, 12]``.  Combined with a ``burst_level``
      hint (usually ``"node"``) the big groups dump more threads under one
      component than it has cpus while small groups leave theirs idle —
      the paper's "unbalanced bubble tree" in which idle cpus must steal
      whole bubbles to stay busy (§3.3.3).
    """
    if groups is not None:
        n_threads = sum(groups)

    def stripe_work(i: int) -> float:
        if not skew or n_threads < 2:
            return work
        return work * (1.0 + skew * i / (n_threads - 1))

    if group is None and groups is None:
        root = bubble(name="app")
        for i in range(n_threads):
            root.insert(thread(stripe_work(i), name=f"stripe{i}",
                               data=f"stripe{i}"))
        return root
    sizes = groups if groups is not None else \
        [group] * (n_threads // group)          # type: ignore[operator]
    root = bubble(name="app")
    j = 0
    for g, size in enumerate(sizes):
        b = bubble(name=f"node_group{g}", burst_level=burst_level)
        for _ in range(size):
            b.insert(thread(stripe_work(j), name=f"stripe{j}",
                            data=f"stripe{j}"))
            j += 1
        root.insert(b)
    return root


def imbalanced_stripes_workload(work: float = 100.0,
                                flat: bool = False) -> Bubble:
    """The canonical unbalanced bubble tree for the stealing experiments:
    six node-hinted groups of widths 2/2/4/4/8/12 over 32 stripes with
    linearly skewed work (skew=1.0).  Small groups leave their node idle,
    big ones overload theirs — only stealing keeps the machine busy.

    ``flat=True`` builds the same 32 skewed stripes without the bubble
    structure (the fair tree for flat-list policies).  Shared by
    ``benchmarks/table2_conduction.py`` and the acceptance tests so both
    always measure the same scenario."""
    return stripes_workload(
        n_threads=32, work=work,
        groups=None if flat else [2, 2, 4, 4, 8, 12],
        skew=1.0, burst_level=None if flat else "node")


# The thrash experiments' calibrated price list (one definition, shared by
# benchmarks/table2_conduction.py and the acceptance tests so both always
# measure the same scenario): a cross-node thread steal costs
# lock 2 + 2 levels * 4 + 1 thread * 1 = 11 quanta — page-migration scale,
# rivalling one of `thrash_stripes_workload`'s tiny stripes — while a bulk
# rebalance pays one base charge plus a descriptor-move fee per task (the
# lock traffic is amortised).
THRASH_COST = StealCostModel(lock_penalty=2.0, level_penalty=4.0,
                             thread_penalty=1.0, rebalance_base=2.0,
                             rebalance_per_move=0.05)


def thrash_stripes_workload(work: float = 6.0, flat: bool = False) -> Bubble:
    """The thrash-prone tree for the adaptive-rebalancing experiments: many
    tiny bubbles plus one fat group, all node-hinted, over skewed stripes.

    24 singleton bubbles and one 24-thread bubble (48 stripes of small
    work, skew=1.0): the fat group bursts on one node and floods its list
    while the singletons finish early, so idle cpus drain the backlog one
    tiny steal at a time — and the per-cycle jitter re-skews the load
    every barrier, so the drain repeats (oscillating load).  Under a
    :class:`~repro.core.scheduler.StealCostModel` each of those many small
    migrations pays the remote lock/latency penalty, which rivals the
    stripes' own work; one proactive rebalance moves the same backlog for
    one bulk charge.  Where :func:`imbalanced_stripes_workload` rewards
    stealing *at all*, this tree is built to reward stealing *cheaply*.

    ``flat=True`` builds the same 48 skewed stripes without the bubble
    structure (the fair tree for flat-list policies).
    """
    return stripes_workload(
        n_threads=48, work=work,
        groups=None if flat else [1] * 24 + [24],
        skew=1.0, burst_level=None if flat else "node")


def fibonacci_workload(n_threads: int, with_bubbles: bool,
                       leaf_work: float = 8.0,
                       group_size: int = 4) -> Bubble:
    """Divide-and-conquer Fibonacci (Fig 5): recursive thread creation.

    Sibling subtrees share data with their parent (the spawned computations
    read the parent's frame and write their results there); the sharing is
    tightest for the smallest subtrees, modelled as one data set per subtree
    of ``group_size`` leaves.  With bubbles, the natural recursion is
    expressed; without, every thread lands in one flat list — exactly the
    paper's "adding bubbles that express the natural recursion".
    """
    import math
    depth = max(1, int(math.ceil(math.log2(max(n_threads, 2)))))
    group_depth = max(0, int(math.log2(max(group_size, 1))))

    def build(d: int, path: str) -> Bubble:
        b = bubble(name=f"fib{path}")
        grp = path[: max(1, len(path) - group_depth)]
        if d == 0:
            b.insert(thread(leaf_work, name=f"leaf{path}", data=f"sub{grp}"))
            return b
        # two recursive calls + the combining continuation; the join runs
        # after its children, so it adds no *concurrent* width (width=0)
        b.insert(build(d - 1, path + "0"))
        b.insert(build(d - 1, path + "1"))
        b.insert(thread(leaf_work * 0.1, name=f"join{path}", data=f"sub{grp}",
                        width=0))
        return b

    tree = build(depth, "r")
    if with_bubbles:
        return tree
    # Without bubbles the threads reach the global list in *creation* order,
    # which interleaves subtrees (children are spawned while other subtrees
    # are already executing) — modelled as a deterministic interleave.
    flat = bubble(name="fib_flat")
    leaves = sorted(tree.threads(), key=lambda t: _h(t.tid, "creation"))
    for t in leaves:
        t.parent = None
        flat.insert(t)
    return flat
