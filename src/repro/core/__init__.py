"""Core bubble-scheduler library (Thibault 2005, adapted to TPU fleets).

Public surface:

* :mod:`repro.core.bubble` — bubble/thread tree (application structure)
* :mod:`repro.core.topology` — hierarchical machine model
* :mod:`repro.core.runqueues` — per-level task lists + two-pass lookup
* :mod:`repro.core.scheduler` — the bubble scheduler (sink/burst/regenerate
  + the hierarchical whole-bubble steal pass)
* :mod:`repro.core.policies` — simple / percpu / bound / bubbles / steal
  strategies (``steal`` = bubbles + work stealing + next-touch migration)
* :mod:`repro.core.runtime` — the shared scheduling-decision loop
  (acquire/bill-cost, first/next-touch data policy, cost-benefit rebalance
  trigger) driven by both the simulator and the serving engine
* :mod:`repro.core.simulator` — discrete-event NUMA simulator (paper repro;
  first-touch and next-touch data-homing policies)
* :mod:`repro.core.planner` — bubble-tree → mesh placement (JAX sharding)
"""

from .bubble import (Bubble, Task, Thread, balanced_tree, bubble, reset_ids,
                     thread)
from .topology import (Level, Topology, bi_xeon_ht, from_mesh_axes,
                       novascale_16, numa_4x4_smt, tpu_pod_slice)
from .runqueues import QueueHierarchy, RunQueue
from .scheduler import ZERO_COST, BubbleScheduler, StealCostModel
from .runtime import SchedulerRuntime, rebalance_worth_it
from .policies import (POLICIES, AdaptivePolicy, BoundPolicy, BubblePolicy,
                       PerCpuPolicy, Policy, SimplePolicy, StealPolicy)
from .simulator import (THRASH_COST, SimResult, Simulator,
                        fibonacci_workload, imbalanced_stripes_workload,
                        stripes_workload, thrash_stripes_workload)
from .planner import (Dim, MeshAxis, Plan, plan_bound, plan_bubbles,
                      plan_simple)

__all__ = [
    "Bubble", "Task", "Thread", "bubble", "thread", "balanced_tree",
    "reset_ids",
    "Level", "Topology", "novascale_16", "bi_xeon_ht", "numa_4x4_smt",
    "tpu_pod_slice", "from_mesh_axes",
    "QueueHierarchy", "RunQueue", "BubbleScheduler", "StealCostModel",
    "ZERO_COST", "SchedulerRuntime", "rebalance_worth_it",
    "POLICIES", "Policy", "SimplePolicy", "PerCpuPolicy", "BoundPolicy",
    "BubblePolicy", "StealPolicy", "AdaptivePolicy",
    "Simulator", "SimResult", "stripes_workload", "fibonacci_workload",
    "imbalanced_stripes_workload", "thrash_stripes_workload", "THRASH_COST",
    "Dim", "MeshAxis", "Plan", "plan_bubbles", "plan_simple", "plan_bound",
]
