"""Pallas TPU kernels for the compute hot-spots of the assigned archs.

* ``flash_attention`` — causal/SWA GQA attention (prefill)
* ``paged_attention`` — block-table paged decode attention (serving)
* ``rglru``           — RG-LRU linear recurrence (RecurrentGemma)
* ``rwkv6``           — WKV with data-dependent decay (Finch)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd wrapper in
``ops.py``; tests sweep shapes/dtypes in interpret mode.
"""

from . import flash_attention, ops, paged_attention, ref, rglru, rwkv6

__all__ = ["flash_attention", "paged_attention", "rglru", "rwkv6", "ops",
           "ref"]
