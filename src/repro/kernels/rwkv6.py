"""Pallas TPU kernel for RWKV6 (Finch) WKV with data-dependent decay.

Per head the state is an (hd, hd) matrix S with the recurrence
    y_t = r_t · (S + u ⊙ k_t v_tᵀ),      S ← diag(w_t) S + k_t v_tᵀ.

TPU adaptation: grid (B, H, chunks) with the chunk axis innermost
(sequential), S carried in VMEM scratch (hd×hd = 64×64 fp32 = 16 KiB —
comfortably VMEM-resident).  The inner time loop forms rank-1 updates in
VREGs; r/k/v/w chunk tiles stream HBM→VMEM once.  The final state is
emitted so prefill hands off to decode.

Validated in interpret mode against the lax.scan oracle ``ref.wkv_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sfin_ref, s_ref, *,
            chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0].astype(jnp.float32)     # (chunk, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (hd,)

    def step(t, carry):
        S, out = carry                          # S: (hd, hd)
        kv = k[t][:, None] * v[t][None, :]      # rank-1 (hd, hd)
        y = ((S + u[:, None] * kv) * r[t][:, None]).sum(axis=0)   # (hd,)
        S = w[t][:, None] * S + kv
        out = jax.lax.dynamic_update_index_in_dim(out, y, t, 0)
        return S, out

    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    S, out = jax.lax.fori_loop(0, chunk, step, (s_ref[...], out0))
    s_ref[...] = S
    y_ref[0, :, 0] = out.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _done():
        sfin_ref[0, 0] = S.astype(sfin_ref.dtype)


def wkv(r, k, v, w, u, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    """r,k,v,w: (B,S,H,hd); u: (H,hd) → (y (B,S,H,hd) f32, S_final
    (B,H,hd,hd) f32)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, sfin = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, hd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, hd), lambda ib, ih, ic: (ih, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, sfin
