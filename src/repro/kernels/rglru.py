"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t·h_{t-1} + b_t.

TPU adaptation: the recurrence is diagonal, so the state is a (N,) vector
per batch row.  The sequence is chunked; the chunk axis is the innermost
grid dimension (sequential on TPU), with the running state carried in VMEM
scratch — HBM traffic is exactly one read of (a, b) and one write of h, the
memory-bound optimum.  Within a chunk the time loop runs in VREGs over the
VMEM-resident tile; the feature axis N (lane-aligned, multiples of 128)
vectorises on the VPU.

Validated in interpret mode against the associative-scan oracle in
``ref.lru_scan_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)          # (chunk, N)
    b = b_ref[0].astype(jnp.float32)
    h0 = carry_ref[...]                        # (N,)

    def step(t, carry_and_out):
        h, out = carry_and_out
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    out0 = jnp.zeros((chunk, a.shape[1]), jnp.float32)
    h, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_ref[0] = out.astype(h_ref.dtype)
    carry_ref[...] = h


def lru_scan(a, b, *, chunk: int = 256, interpret: Optional[bool] = None):
    """a, b: (B, S, N) → h: (B, S, N) (fp32 state math)."""
    B, S, N = a.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda ib, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, N), lambda ib, ic: (ib, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N,), jnp.float32)],
        interpret=interpret,
    )(a, b)
