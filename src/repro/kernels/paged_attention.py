"""Pallas TPU paged decode attention: K/V read through a block table.

The serving engine stores each slot's KV in fixed-size *pages* of a shared
pool — ``(num_pages, page_size, K, hd)`` — and a per-slot *block table* of
page indices.  A KV migration (steal, park/splice, rebalance) is then a
block-table edit: no tensor moves, the pages stay where they are.  This
kernel is the decode path that makes that layout free to read: one query
token per slot attends over its pages by indexing the pool through the
scalar-prefetched block table.

Structure follows ``flash_attention._kernel`` (the online-softmax VMEM
scratch pattern): the page axis is the innermost grid dimension, iterated
sequentially per (slot, kv-head), so (m, l, acc) carry across pages.  The
block table and per-slot lengths ride in scalar-prefetch memory
(``PrefetchScalarGridSpec``) because the K/V BlockSpec index map *is* the
table lookup — the DMA for page ``i`` of slot ``b`` fetches pool page
``tables[b, i]``.

Layout: q ``(B, K, g, hd)`` (GQA groups folded out of H = K*g), pools
``(num_pages, page_size, K, hd)``, tables ``(B, pages_per_slot)`` int32,
lengths ``(B,)`` int32 — the number of valid tokens *including* the one
just written; the query is the token at position ``lengths - 1``.  Unused
table entries must be 0: page 0 is the engine's trash page, never valid,
and masked off by the length test.  On real TPUs ``page_size`` should be a
sublane multiple (8 for f32); interpret mode (the CPU CI path) has no such
constraint.  Validated against ``ref.sdpa_ref`` / ``ref.paged_sdpa_ref``
in interpret mode by ``tests/test_paged_attention.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float,
            window: Optional[int], page_size: int, npages: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page_size, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, ps)

    # position of each pool column = page rank * page_size + offset; valid
    # while < lengths[b] (and, for SWA, within `window` of the query).  A
    # page past the slot's used count points at the trash page — every one
    # of its positions fails the length test, so its contents never leak.
    length = len_ref[b]
    k_pos = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = k_pos < length
    if window is not None:
        mask &= k_pos > (length - 1 - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (g,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                      # (g, ps)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ip == npages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_attn(q, k_pool, v_pool, tables, lengths, *,
               window: Optional[int] = None, scale: float = 1.0,
               interpret: Optional[bool] = None):
    """One decode step of paged attention.

    q ``(B, K, g, hd)``, pools ``(P, page_size, K, hd)``, tables
    ``(B, pages_per_slot)`` int32, lengths ``(B,)`` int32.  Returns
    ``(B, K, g, hd)``.  Rows with ``lengths == 0`` (free slots) produce
    finite garbage — callers discard them, exactly like the dense path.
    """
    B, K, g, hd = q.shape
    P, page_size, Kp, hdp = k_pool.shape
    assert (Kp, hdp) == (K, hd), (k_pool.shape, q.shape)
    assert v_pool.shape == k_pool.shape
    npages = tables.shape[1]
    assert tables.shape == (B, npages) and lengths.shape == (B,)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(_kernel, scale=scale, window=window,
                             page_size=page_size, npages=npages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, npages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b, h, ip, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, ip, tbl, ln: (tbl[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, ip, tbl, ln: (tbl[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, ip, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, hd), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, g, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)
