"""Jit'd public wrappers for the Pallas kernels.

Each op auto-selects interpret mode on CPU (the kernels are TPU-targeted;
interpret executes the kernel body in Python for validation) and exposes the
same signature as its ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax

from . import flash_attention as _fa
from . import rglru as _rglru
from . import rwkv6 as _rwkv6


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk"))
def mha(q, k, v, *, causal=True, window=None, scale=1.0, bq=256, bk=256):
    return _fa.mha(q, k, v, causal=causal, window=window, scale=scale,
                   bq=bq, bk=bk)


@functools.partial(jax.jit, static_argnames=("chunk",))
def lru_scan(a, b, *, chunk=256):
    return _rglru.lru_scan(a, b, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv(r, k, v, w, u, *, chunk=128):
    return _rwkv6.wkv(r, k, v, w, u, chunk=chunk)
