"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sdpa_ref(q, k, v, *, causal=True, window: Optional[int] = None,
             scale=1.0):
    """(B,S,H,hd) GQA attention, materialised softmax."""
    from repro.models.attention import _mask, _sdpa
    B, S = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    m = _mask(pos, pos, window) if causal else None
    return _sdpa(q, k, v, m, scale)


def lru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan.  (B,S,N) f32."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(
        comb, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h


def wkv_ref(r, k, v, w, u):
    """RWKV6 time-mix oracle.  All (B,S,H,hd) f32; u (H,hd).
    Returns (y, S_final)."""
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))

    def step(S_, inp):
        r_, k_, v_, w_ = inp
        kv = k_[..., :, None] * v_[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", r_, S_ + u[None, :, :, None] * kv)
        S_ = w_[..., :, None] * S_ + kv
        return S_, out

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    Sf, y = jax.lax.scan(step, S0, (rf.swapaxes(0, 1), kf.swapaxes(0, 1),
                                    vf.swapaxes(0, 1), wf.swapaxes(0, 1)))
    return y.swapaxes(0, 1), Sf


def paged_sdpa_ref(q, k_pool, v_pool, tables, lengths, *,
                   window: Optional[int] = None, scale=1.0):
    """Paged decode-attention oracle: gather pages dense, then run the
    exact masked-softmax math of ``models.attention.decode_attention``.

    q ``(B, K, g, hd)``, pools ``(P, page_size, K, hd)``, tables
    ``(B, pages_per_slot)`` int32, lengths ``(B,)`` — valid tokens
    including the one at the query's position ``lengths - 1``.  Because
    the gathered layout puts position ``t`` at column ``t`` and masks the
    rest with the same ``-1e30`` the dense path uses, a pool whose
    ``pages_per_slot * page_size`` equals the dense cache length yields
    *bit-identical* logits to ``decode_attention`` (masked columns
    underflow to exactly zero) — which is what lets the paged serving
    backend assert stream equality against the dense one.
    """
    B, K, g, hd = q.shape
    page_size = k_pool.shape[1]
    npages = tables.shape[1]
    T = npages * page_size
    k = k_pool[tables].reshape(B, T, K, hd)
    v = v_pool[tables].reshape(B, T, K, hd)
    tpos = jnp.arange(T)[None, :]
    valid = tpos < lengths[:, None]
    if window is not None:
        valid &= tpos > (lengths[:, None] - 1 - window)
    qg = q[:, None]                                     # (B, 1, K, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst",
                        qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return out[:, 0]                                    # (B, K, g, hd)
