"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sdpa_ref(q, k, v, *, causal=True, window: Optional[int] = None,
             scale=1.0):
    """(B,S,H,hd) GQA attention, materialised softmax."""
    from repro.models.attention import _mask, _sdpa
    B, S = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    m = _mask(pos, pos, window) if causal else None
    return _sdpa(q, k, v, m, scale)


def lru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan.  (B,S,N) f32."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(
        comb, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h


def wkv_ref(r, k, v, w, u):
    """RWKV6 time-mix oracle.  All (B,S,H,hd) f32; u (H,hd).
    Returns (y, S_final)."""
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))

    def step(S_, inp):
        r_, k_, v_, w_ = inp
        kv = k_[..., :, None] * v_[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", r_, S_ + u[None, :, :, None] * kv)
        S_ = w_[..., :, None] * S_ + kv
        return S_, out

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    Sf, y = jax.lax.scan(step, S0, (rf.swapaxes(0, 1), kf.swapaxes(0, 1),
                                    vf.swapaxes(0, 1), wf.swapaxes(0, 1)))
    return y.swapaxes(0, 1), Sf
