"""Pallas TPU flash attention (causal / sliding-window, GQA pre-repeated).

TPU adaptation of the flash algorithm: Q/K/V tiles live in VMEM with
MXU-aligned (128-multiple) block shapes; the KV axis is the innermost grid
dimension, which Pallas TPU iterates sequentially per (batch, head, q-block),
so the online-softmax state (m, l, acc) is carried in VMEM scratch across KV
steps — the HBM→VMEM pipeline streams K/V tiles while the MXU consumes them.

Layout: (B, H, S, hd).  ``hd`` up to 256 fits a lane tile; block sizes are
clamped to the sequence and padded shapes are the caller's responsibility
(``ops.mha`` pads).  Validated in interpret mode against ``ref.sdpa_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = None
    if causal:
        mask = k_pos <= q_pos
    if window is not None:
        wmask = k_pos > (q_pos - window)
        mask = wmask if mask is None else (mask & wmask)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                      # (bq, bk)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_bhsd(q, k, v, *, causal: bool = True,
               window: Optional[int] = None, scale: float = 1.0,
               bq: int = 256, bk: int = 256,
               interpret: Optional[bool] = None):
    """q,k,v: (B,H,S,hd) with equal head counts (repeat GQA beforehand)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=_scratch(bq, hd),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq, hd):
    """VMEM online-softmax state: acc (bq,hd), m (bq,), l (bq,)."""
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32)]


def mha(q, k, v, *, causal: bool = True, window: Optional[int] = None,
        scale: float = 1.0, bq: int = 256, bk: int = 256,
        interpret: Optional[bool] = None):
    """(B,S,H,hd) GQA entry point: repeats KV heads, handles layout."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        g = H // K
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_bhsd(qt, kt, vt, causal=causal, window=window, scale=scale,
                     bq=bq, bk=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
