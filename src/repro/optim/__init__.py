from . import adamw, compression
from .adamw import AdamWConfig, AdamWState

__all__ = ["adamw", "compression", "AdamWConfig", "AdamWState"]
