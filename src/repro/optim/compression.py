"""Int8 error-feedback gradient compression for cross-pod all-reduce.

Crossing the ``pod`` axis is DCN — the "NUMA factor" of the fleet.  A
standard distributed-optimization trick is to compress the gradient before
the expensive hop and keep a local error-feedback accumulator so the
quantisation error is re-injected the next step (1-bit Adam / EF-SGD
lineage).

Usage (see ``launch.train``): gradients are all-reduced over ``data``
in full precision (cheap ICI), then quantised per-tensor to int8 with a
fp32 scale, all-reduced over ``pod`` (16x fewer DCN bytes than fp32,
4x fewer than bf16), dequantised, and the residual fed back.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any            # same tree as grads, bf16


def init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params))


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, ef: EFState):
    """grads+residual → (quantised tree of (q, scale), new residual).

    Under jit the duplicated quantize calls are CSE'd; structuring as two
    maps keeps the pytree bookkeeping trivial."""
    def q_fn(g, r):
        x = g.astype(jnp.float32) + r.astype(jnp.float32)
        return quantize(x)

    def r_fn(g, r):
        x = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, s = quantize(x)
        return (x - dequantize(q, s)).astype(jnp.bfloat16)

    qs = jax.tree.map(q_fn, grads, ef.residual)
    res = jax.tree.map(r_fn, grads, ef.residual)
    return qs, EFState(residual=res)


def decompress_tree(qs):
    return jax.tree.map(lambda t: dequantize(*t),
                        qs, is_leaf=lambda t: isinstance(t, tuple))
