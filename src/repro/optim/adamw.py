"""AdamW with mixed-precision state layout sized for 16GB-HBM chips.

State per parameter: fp32 master copy + bf16 first/second moments
(2+4 = 8 bytes/param opt state, 2 bytes param, 2 bytes grad → 12 B/param,
which is what lets grok-1-314b train on a 256-chip v5e pod).  The moments
are stored bf16 with the update math in fp32 (load-convert), a standard
large-scale trade; ZeRO-1 sharding of this state over the ``data`` axis is
applied by the sharding layer (`distributed.sharding.opt_specs`), not here —
the optimizer math is layout-agnostic.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    master: Any                # fp32 params
    m: Any                     # bf16
    v: Any                     # bf16


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    moment_dtype: Any = jnp.bfloat16


def init(params) -> AdamWState:
    # copy=True: with fp32 params astype would alias the parameter buffer,
    # and donating params+master to the train step would double-donate
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    bf = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      master=jax.tree.map(f32, params),
                      m=jax.tree.map(bf, params),
                      v=jax.tree.map(bf, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply(grads, state: AdamWState, cfg: AdamWConfig,
          param_dtype=jnp.bfloat16):
    """Returns (new_params in ``param_dtype``, new_state)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = _schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mstr, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / b1t
        vh = v32 / b2t
        new_master = mstr - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                  + cfg.weight_decay * mstr)
        return (new_master,
                m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, grads, state.master, state.m, state.v)
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    return new_params, AdamWState(step=step, master=new_master,
                                  m=new_m, v=new_v)
