"""llava-next-34b — VLM: anyres-tiled vision frontend + dense LM backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  60L d_model=7168
56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower is a STUB per
the brief: ``input_specs`` provides precomputed patch embeddings
(anyres tiling ≈ 5 tiles x 576 patches = 2880 tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20_480, vocab=64_000,
    frontend="vision", frontend_tokens=2_880,
)
