"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1:2 pattern.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000.  Griffin pattern: two recurrent blocks per local
(sliding-window) attention block; window 2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256_000,
    head_dim=256,
    window=2_048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    logits_softcap=30.0,
)
