"""rwkv6-3b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536;
head size 64 → 40 wkv heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8_960, vocab=65_536,
    head_dim=64,
    block_pattern=("rwkv",),
)
