"""chatglm3-6b — dense GQA, 2d RoPE (applied to half the head dims).

[arXiv:2406.12793; hf]  28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13_696, vocab=65_024,
    rope_fraction=0.5,
)
