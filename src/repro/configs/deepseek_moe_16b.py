"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf]  28L d_model=2048 16H (GQA kv=16, i.e. MHA)
d_ff=1408 (per expert) vocab=102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1_408, vocab=102_400,
    n_experts=64, top_k=6, n_shared_experts=2,
)
