"""seamless-m4t-medium — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]  12L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=256206.  The speech/text frontend is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings; this config is the
transformer backbone only (12 enc + 12 dec layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4_096, vocab=256_206,
    enc_layers=12,
    frontend="audio", frontend_tokens=4_096,
)
