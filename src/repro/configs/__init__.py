"""Architecture registry: ``--arch <id>`` resolution."""

from importlib import import_module

from repro.models.config import ModelConfig

ARCHS = [
    "recurrentgemma-9b", "grok-1-314b", "deepseek-moe-16b", "chatglm3-6b",
    "yi-6b", "internlm2-20b", "h2o-danube-3-4b", "seamless-m4t-medium",
    "rwkv6-3b", "llava-next-34b",
]


def _modname(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = import_module(f"repro.configs.{_modname(arch)}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
