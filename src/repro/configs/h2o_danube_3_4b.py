"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000; SWA window 4096 makes it 500k-decode capable.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10_240, vocab=32_000,
    head_dim=120,
    window=4_096,
)
