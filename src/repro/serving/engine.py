"""Serving engine: continuous batching as the second SchedulerRuntime client.

Requests are *threads* (work = tokens still to decode, data = the gang's KV
page-group id); requests sharing a prompt prefix or an SLA class are grouped
into *bubbles*.  The engine owns a fixed-size decode batch and maps it onto
the scheduling model exactly as the paper prescribes for any workload:

=================  ==========================================================
scheduler concept  serving meaning
=================  ==========================================================
cpu (leaf)         decode batch slot
level              ``pod`` > ``host`` > ``page``: DCN shards, hosts within a
                   pod, and KV page groups (slots sharing a cache page) —
                   the full hierarchy when ``pods``/``hosts`` > 1, just
                   ``page`` on a single host
data object        a gang's KV state (``Thread.data`` = gang id)
steal              an idle slot pulls a queued gang from a loaded page
                   group — possibly across hosts, where the per-level cost
                   table prices the DCN crossing ~10x a page crossing
next touch         first post-migration admission re-homes the gang's KV via
                   a *batched* splice of parked per-request states — not the
                   old per-request re-prefill path
rebalance          queue-depth skew across page groups triggers one bulk
                   LPT re-spread (`BubbleScheduler.rebalance`), cost-gated
capacity           per-page-group HBM byte budgets: a full page group
                   refuses loot (the steal survey skips it, admission parks
                   the gang) instead of thrashing KV it cannot hold
=================  ==========================================================

The engine drives the same :class:`~repro.core.runtime.SchedulerRuntime`
loop as the discrete simulator — ``acquire`` (lookup + steal + cost
billing), ``touch`` (first/next-touch KV homing), ``rebalance_worth_it``
(the AdaptivePolicy-style cost-benefit trigger, fed by decode-gang queue
depths instead of steal-attempt windows).  ``mode="admission"`` keeps the
pre-runtime behaviour (no steal, no rebalance, first-touch homing) as the
measurable baseline for ``benchmarks/serve_gangs.py``.

Cost has a physical meaning here: a :class:`StealCostModel` penalty accrued
by a slot's scheduler call (remote page-group locks, KV drag) is billed as
*admission-latency steps* — the slot sits out that many engine steps before
its next decode, so steal-happy schedules pay for their migrations in the
engine's own currency.

**Execution follows the placement hierarchy** (the paper's core claim
applied to the execution substrate, not just the decisions): on a
multi-host fleet each host owns an independent decode batch — one
``decode_step`` call (one jit, one KV shard) per host per engine step —
and fresh same-length prompts admitted in one wave are prefilled in one
batched call per host (``prefill_wave``) instead of a per-request loop.
A host whose batch is empty skips its decode entirely, which is exactly
the per-shard latency a flat whole-fleet batch cannot model.  Slot
occupancy within a host batch is still a mask (empty slots decode padding
at negligible marginal cost on TPU).  Sharding the execution never
changes the decoded streams: slots are independent in every backend, so
per-host batches produce bit-identical tokens to the historical global
batch (property-tested across fleet topologies), and a single-host engine
*is* the historical global batch, byte for byte.

The model is behind a small backend interface so the scheduler stack can
be exercised hermetically: :class:`JaxModelBackend` runs the real zoo,
:class:`StubModelBackend` is a deterministic numpy stand-in (no jit
compile) for tests and CI benchmarks.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.bubble import Bubble, Thread, bubble, thread
from repro.core.policies import BubblePolicy, StealPolicy
from repro.core.runtime import SchedulerRuntime
from repro.core.scheduler import StealCostModel
from repro.core.topology import Level, Topology

from .workload import goodput_under_sla, percentile

# The serving price list: a steal pays remote page-group lock traffic plus a
# per-level / per-request KV drag, a rebalance pays one bulk charge — all in
# engine steps (admission latency).  Small relative to typical decode
# lengths, so stealing stays profitable but not free; the queue-depth
# rebalance trigger needs the nonzero prices to pass its cost-benefit test.
#
# The ``level_table`` prices the multi-host boundaries: dragging KV across a
# ``host`` pays DCN round-trips (~10x the on-chip page shuffle once the
# extra tree distance is counted in) and across a ``pod`` pays the
# data-center network on top.  Single-host topologies have neither level,
# so every pre-existing single-host schedule is priced — and therefore
# traced — identically.
SERVE_COST = StealCostModel(lock_penalty=0.5, level_penalty=0.25,
                            thread_penalty=0.125, rebalance_base=1.0,
                            rebalance_per_move=0.125,
                            level_table=(("host", 3.0), ("pod", 6.0)))

# What a DCN-naive scheduler believes: the same prices with the per-level
# table dropped — a cross-host steal looks barely dearer than a cross-page
# one.  Derived from SERVE_COST so the two can only ever differ in the
# table (the multihost benchmark's validity depends on exactly that).
# Pair it with ``bill_model=SERVE_COST`` and the engine keeps choosing
# remote loot it must then pay real DCN latency for: the measurable
# baseline for ``serve/multihost_steal_speedup``.
FLAT_SERVE_COST = dataclasses.replace(SERVE_COST, level_table=())

# The *bandwidth-priced* machine: the same boundary bases, plus a per-byte
# term — a transfer's bill scales with the KV bytes it drags (``kv_bytes``
# x live threads, the engine's own HBM-ledger ruler wired into the
# scheduler as ``bytes_cb``).  Dragging a fat gang across a ``host``
# boundary now costs proportionally more than a singleton at the same
# distance, which is what the DCN actually charges.  The rates are
# asymmetric on purpose: within-pod (``host``) moves ride the fast
# interconnect (cheap per byte), cross-``pod`` moves ride the DCN — so a
# byte-aware survey keeps heavy KV inside the pod while a byte-naive one
# sees only the flat bases, whose cross/same ratio the per-byte term
# roughly doubles.  A bandwidth-naive scheduler believes ``SERVE_COST``
# (flat boundary tolls) while paying ``BW_SERVE_COST`` (``bill_model``):
# the measurable baseline for ``serve/bandwidth_priced_speedup``.  With
# every ``per_byte`` zero the triple form prices bit-identically to the
# pair form, so SERVE_COST itself — and every golden trace — is untouched.
BW_SERVE_COST = dataclasses.replace(
    SERVE_COST, level_table=(("host", 3.0, 0.25), ("pod", 6.0, 2.0)))

# Levels a ``slots_topology`` fleet deliberately does NOT price in the
# level table: crossings below ``host`` (and the degenerate ``batch`` /
# ``pod`` roots) fall back to the flat ``level_penalty`` per level
# crossed — on-chip shuffles are latency, not DCN bandwidth.  The cost-
# model coverage test pins every topology level to either this set or a
# ``level_table`` entry, so a new level cannot silently price at zero.
SERVE_FREE_LEVELS = frozenset({"batch", "page", "slot"})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    prio: int = 0
    gang: Optional[str] = None         # co-schedule group (shared prefix)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # -- SLA / latency ledger (open-loop traffic) --
    # ``sla`` is the submitted CONTRACT class — immutable, it is what the
    # request's TTFT/goodput are judged by.  ``tier`` is the SCHEDULING
    # class — starts equal to ``sla`` and sinks under the multilevel-
    # feedback demotion rule (a long-runner stops competing as
    # interactive, but is still *measured* as one).
    sla: Optional[str] = None
    tier: Optional[str] = None
    submit_step: int = 0               # engine step the request was queued
    first_token_step: Optional[int] = None   # step the prefill token landed
    last_token_step: Optional[int] = None    # step of the latest token
    finish_step: Optional[int] = None        # step the request completed
    # -- agentic sessions (tool calls / multi-turn) --
    # ``tool_calls`` is a tuple of ``(at_tokens, think_steps)`` markers:
    # when the request's emitted-token count reaches ``at_tokens`` it
    # blocks on an external event (a tool response) for ``think_steps``
    # engine steps (``None`` = until the client calls ``engine.wake``).
    # No tokens are injected on wake, so a request's stream is a pure
    # function of its prompt — identical with or without the sleeps.
    tool_calls: tuple = ()
    next_call: int = 0                 # index of the next unfired marker
    wake_step: Optional[int] = None    # step the latest tool response landed
    # a resumed service interval: the first token after a park/sleep is
    # not an inter-token gap (the request was not being served) — the
    # latency ledger records wake-to-token instead when ``wake_step`` is
    # set, and nothing otherwise
    service_break: bool = False


@dataclasses.dataclass
class EngineStats:
    """Engine-side ledger (scheduler counters live in ``sched.stats``).

    Counting conventions worth pinning down (previously folklore):

    * ``prefills`` counts **requests** prefilled (each fresh prompt once,
      however they are batched); ``prefill_waves`` counts the **backend
      calls** that ran them — with wave batching on, ``prefill_waves <=
      prefills`` and the gap is the batching win.
    * ``kv_splices`` counts batched splice **ops** (one per host batch per
      admission wave), ``kv_spliced_slots`` the slots they wrote.
    * ``hbm_slot_waits`` vs ``hbm_refusals`` — the two HBM events are
      distinct and mode-exclusive: a *wait* is a capacity-**aware** slot
      sitting out an admission wave because its page group is at budget
      (one count per slot per step with work queued — a backpressure
      gauge, no work wasted); a *refusal* is a capacity-**blind** claim
      bounced at splice time, after the scheduler call and any steal bill
      already ran — pure wasted work.  Comparing the two across modes is
      how ``serve/hbm_pressure_refusal_speedup`` reads.
    * ``host_decode_steps[h]`` / ``host_active_slots[h]`` — the per-host
      execution ledger: decode calls host ``h`` actually ran (it skips
      steps where its batch is empty) and the cumulative occupied-slot
      count over those calls.  Host skew that placement hides shows up
      here: a flooded host runs every step near-full while its neighbours
      idle.  Single-host engines have one entry (the whole batch).
    * ``host_skipped_steps[h]`` — straggler stalls: engine steps host
      ``h`` had occupied slots but its speed credit had not reached a
      whole decode yet (``host_speed[h] < 1``), so its batch sat still.
      Always zero at nominal speed.  Effective per-host throughput is
      ``host_active_slots[h] / engine steps`` (the ``host_throughput``
      counter): a 0.5x host with full slots decodes half the tokens per
      engine step a nominal host would.
    * ``gang_splits`` / ``gang_split_members`` — HBM-aware gang
      splitting: whole-gang admissions the HBM ledger refused that were
      cheaper to split across sibling page groups (the bubble expanded
      one level, overflow members re-homed) than to park until the home
      group drained; ``gang_split_members`` counts the members actually
      moved to siblings.
    * ``host_kills`` / ``host_joins`` / ``orphaned`` / ``kv_restores`` /
      ``reprefills`` — the elastic-fleet ledger: hosts removed/added
      live, resident requests whose KV died with a host, and how each
      orphan was brought back (snapshot restore + replay vs re-prefill
      from scratch — whichever the cost model quoted cheaper).
    * the agentic ledger: ``sleeps`` counts tool-call slot releases
      (sleep-and-release mode), ``holds`` tool calls that kept their slot
      (the baseline) and ``hold_slot_steps`` the slot-steps those held
      slots sat idle; ``wakes`` counts tool responses delivered, split
      ``wake_home`` (spliced back under the session's old page group) vs
      ``wake_away`` (the wake-affinity quote found somewhere cheaper and
      billed the move); ``stale_evictions`` sessions whose parked KV was
      dropped past ``session_ttl`` and ``wake_reprefills`` the wakes that
      consequently had to rebuild their continuation from the full
      history.
    """

    prefills: int = 0            # fresh REQUESTS prefilled (not calls)
    prefill_waves: int = 0       # batched prefill CALLS issued
    kv_splices: int = 0          # batched splice ops issued
    kv_spliced_slots: int = 0    # slots written by those splices
    kv_parks: int = 0            # per-request KV states parked
    kv_migrations: int = 0       # next-touch re-homes of a gang's KV
    kv_page_moves: int = 0       # ...of which crossed page groups
    kv_host_moves: int = 0       # ...of which crossed hosts (DCN traffic)
    rebalances: int = 0          # queue-depth-triggered re-spreads
    local_rebalances: int = 0    # ...of which host-scoped (DCN-free)
    stall_steps: float = 0.0     # admission latency billed by the cost model
    preemptions: int = 0         # SLA preemption firings (one victim each)
    preempt_parks: int = 0       # requests parked by those firings
    demotions: int = 0           # multilevel-feedback tier demotions
    hbm_slot_waits: int = 0      # aware: full-group slots skipping waves
    hbm_refusals: int = 0        # blind: claims bounced at splice time
    gang_splits: int = 0         # gangs split across sibling page groups
    gang_split_members: int = 0  # members re-homed by those splits
    # elastic-fleet ledger (kill_host / join_host)
    host_kills: int = 0          # hosts removed live
    host_joins: int = 0          # hosts added live
    orphaned: int = 0            # residents whose KV died with a host
    kv_restores: int = 0         # orphans resumed from the KV snapshot store
    reprefills: int = 0          # orphans recomputed from scratch
    # agentic ledger (tool-call sleep/wake)
    sleeps: int = 0              # tool calls that released their slot
    holds: int = 0               # tool calls that kept it (baseline)
    hold_slot_steps: int = 0     # slot-steps held slots sat idle thinking
    wakes: int = 0               # tool responses delivered
    wake_home: int = 0           # ...spliced back under the home page group
    wake_away: int = 0           # ...re-homed by the wake-affinity quote
    wake_reprefills: int = 0     # wakes that rebuilt KV from history
    stale_evictions: int = 0     # sleeping sessions whose KV hit session_ttl
    # per-host execution ledger (sized by the engine at construction)
    host_decode_steps: list = dataclasses.field(default_factory=list)
    host_active_slots: list = dataclasses.field(default_factory=list)
    host_skipped_steps: list = dataclasses.field(default_factory=list)


def _fanout(sizes: list[int]):
    """Collapse a uniform per-parent fanout list to its int form (keeps
    ``Topology.describe()`` and the goldens' layouts identical for the
    historical uniform cases)."""
    return sizes[0] if len(set(sizes)) == 1 else sizes


def slots_topology(n_slots: int, group: int = 4, *, hosts: int = 1,
                   pods: int = 1, page_factor: float = 2.0,
                   host_factor: float = 4.0,
                   dcn_factor: float = 8.0) -> Topology:
    """Model the decode fleet as a hierarchy: pods shard the fleet across
    the DCN, hosts within a pod each own a decode batch, slot groups share
    a KV page (affinity level), slots are the leaves.

    ``n_slots`` is the total slot count and need not divide evenly at any
    level: slots are dealt across the ``pods * hosts`` hosts (sizes differ
    by at most one), each host's slots are split into KV page groups of at
    most ``group``, and **every** slot is a schedulable leaf (the old
    ``n_slots // group`` derivation silently dropped the remainder —
    ``n_slots=9, group=4`` built 2x4 leaves and slot 8 could never be
    admitted to).  Ragged splits everywhere ride on the per-parent fanout
    lists :class:`~repro.core.topology.Level` grew for exactly this.

    Level layout: ``batch > [pod >] [host >] page > slot`` — the ``pod``
    level appears only when ``pods > 1`` and the ``host`` level whenever
    the fleet has more than one host, so the historical single-host
    topology (and every golden trace over it) is byte-identical.
    """
    assert n_slots >= 1, n_slots
    assert hosts >= 1 and pods >= 1, (hosts, pods)
    n_hosts = hosts * pods
    assert n_slots >= n_hosts, \
        f"need >=1 slot per host ({n_slots} slots, {n_hosts} hosts)"
    base, rem = divmod(n_slots, n_hosts)
    host_slots = [base + 1] * rem + [base] * (n_hosts - rem)
    page_counts: list[int] = []           # pages per host, host order
    slot_sizes: list[int] = []            # slots per page, page order
    for hs in host_slots:
        groups = max(-(-hs // group), 1)             # ceil division
        b, r = divmod(hs, groups)
        page_counts.append(groups)
        slot_sizes += [b + 1] * r + [b] * (groups - r)
    levels = [Level("batch", 1)]
    if pods > 1:
        levels.append(Level("pod", pods, factor=dcn_factor))
    if n_hosts > 1:
        levels.append(Level("host", hosts if pods > 1 else n_hosts,
                            factor=host_factor))
    levels += [Level("page", _fanout(page_counts), factor=page_factor),
               Level("slot", _fanout(slot_sizes))]
    return Topology(levels)


# ---------------------------------------------------------------------------
# model backends
# ---------------------------------------------------------------------------

class JaxModelBackend:
    """The real model zoo: jitted whole-batch decode + per-request prefill.

    Which axis of each state leaf carries the batch is *inferred*, not
    guessed: ``api.batch_axis_spec`` pins it per leaf by comparing state
    shapes at two batch sizes (``-1`` marks batch-free leaves, passed
    through untouched).  The old ``ndim >= 2`` heuristic assumed "axis 1
    if the leaf has one" — true for every reps-stacked cache today, but it
    silently skipped genuine 1-D per-slot leaves, and a skipped leaf means
    a spliced request resumes with another request's state."""

    def __init__(self, cfg, params, cache_len: int):
        import jax  # deferred: stub-mode users never pay the import
        from repro.models import api
        self._jax = jax
        self._api = api
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self._decode = jax.jit(api.make_decode_fn(cfg))
        self._prefill = api.make_prefill_fn(cfg, cache_len)
        self._axes = api.batch_axis_spec(
            lambda n: api.lm.init_state(cfg, n, cache_len))

    def init(self, n_slots: int) -> tuple:
        states = self._api.lm.init_state(self.cfg, n_slots, self.cache_len)
        return states, np.zeros((n_slots, 1), np.int32)

    def _slice(self, states, i: int):
        """One sequence's state: index the batch axis of every batch leaf
        (keepdims, so slices concatenate back in a splice)."""
        lax = self._jax.lax
        return self._jax.tree.map(
            lambda ax, b: b if ax < 0
            else lax.index_in_dim(b, i, ax, keepdims=True),
            self._axes, states)

    def prefill(self, prompt: np.ndarray) -> tuple[int, object]:
        jnp = self._jax.numpy
        logits, st = self._prefill(self.params, {"tokens":
                                                 jnp.asarray(prompt[None, :])})
        tok = int(jnp.argmax(logits, axis=-1).astype(jnp.int32)[0])
        return tok, st

    def prefill_wave(self, prompts: list) -> list:
        """Prefill a wave of same-length prompts in ONE model call.

        ``lm.prefill`` is natively batched ((B, S) tokens → (B, V) last
        logits + batched states), so the wave costs one forward pass; the
        batched state is split back into per-sequence slices so the
        admission splice can route each to its slot.  Returns
        ``[(first_token, state), ...]`` in prompt order — identical values
        to ``prefill`` run per request."""
        jnp = self._jax.numpy
        logits, st = self._prefill(self.params,
                                   {"tokens": jnp.asarray(np.stack(prompts))})
        toks = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return [(int(toks[i]), self._slice(st, i))
                for i in range(len(prompts))]

    def decode(self, tokens: np.ndarray, states) -> tuple[np.ndarray, object]:
        jnp = self._jax.numpy
        logits, states = self._decode(self.params, jnp.asarray(tokens), states)
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # (B,)
        return next_tok, states

    def splice(self, states, pairs: list[tuple[int, object]]):
        """Write several single-sequence states into their batch slots in
        ONE traversal — the batched next-touch splice (the old engine
        spliced once per request)."""
        jnp = self._jax.numpy
        slots = jnp.asarray([s for s, _ in pairs])

        def write(ax, b, *ones):
            if ax < 0:
                return b
            idx = (slice(None),) * ax + (slots,)
            return b.at[idx].set(jnp.concatenate(ones, axis=ax))

        return self._jax.tree.map(write, self._axes, states,
                                  *[st for _, st in pairs])

    def extract(self, states, slot: int):
        return self._slice(states, slot)

    def peek(self, states, slot: int):
        """Non-mutating read of one slot's state — what the KV snapshot
        store writes on its cadence.  Identical to :meth:`extract` here
        (slicing copies); a distinct name because the *paged* backend's
        extract is a destructive table edit and must never be used for
        snapshots — the engine requires ``peek`` to enable a ``kv_store``."""
        return self._slice(states, slot)


class _PagedShard:
    """One execution group's paged KV: device-side pools (inside
    ``states``) plus the host-side page metadata the backend edits —
    the block table, per-slot lengths, the free list, and per-slot page
    ownership.  The engine holds this object opaquely as the group's
    "states"."""

    __slots__ = ("states", "table", "lengths", "free", "slot_pages")

    def __init__(self, states, table, lengths, free, slot_pages):
        self.states = states          # list[stage] of tuple[pos] pytrees
        self.table = table            # (n_slots, pages_per_slot) np.int32
        self.lengths = lengths        # (n_slots,) np.int32
        self.free = free              # allocatable pool page ids (0 = trash)
        self.slot_pages = slot_pages  # slot -> [page ids], allocation order


class PagedJaxModelBackend:
    """The model zoo on paged KV: a steal/park/splice is a block-table
    edit, not a tensor copy.

    The KV layout mirrors the engine's page groups: every attention layer
    reads K/V from a shared page pool through one per-shard block table
    (``models.paged``), so the state that used to *move* with a request —
    per-layer ``(B, C, K, hd)`` cache rows — is pinned, and only metadata
    moves:

    * ``extract`` (park, steal-time KV drag) hands back the slot's page
      ids + recurrent-state slices and zeroes its table row — no pool
      read;
    * ``splice`` of a parked handle into the same shard re-points the new
      slot's table row at those pages — no pool write (counted in
      ``stats["table_splices"]``); only a *cross-shard* splice (a DCN
      move between host batches) copies pages between pools
      (``stats["pool_copies"]``, in pages);
    * fresh prefills are the one real pool write: the prompt's K/V pages
      are scattered in, batched per layer per admission wave
      (``stats["pool_page_writes"]``).

    Decode stays one jit per host batch with a stable signature
    ``(params, tokens, states, table, lengths)``.  Pages are allocated
    lazily as a slot's length crosses page boundaries; page 0 is the
    trash page free slots decode into.  Recurrent leaves (rwkv6/rglru —
    fixed-size O(1) states) ride the same explicit batch-axis spec as the
    dense backend: they are spliced by value, which for an O(1) state *is*
    the cheap move.

    Streams are identical to :class:`JaxModelBackend` by construction
    when ``cache_len`` has no sliding-window ring (see
    ``kernels.ref.paged_sdpa_ref``); the serving benchmark and the engine
    property tests assert it token-for-token.
    """

    def __init__(self, cfg, params, cache_len: int, *, page_size: int = 16,
                 use_kernel: bool = False, slack_slots: Optional[int] = None,
                 hbm_bytes: Optional[int] = None):
        import jax
        from repro.models import api, lm, paged
        assert not cfg.enc_layers, "paged serving: decoder-only models"
        assert cache_len % page_size == 0, (cache_len, page_size)
        self._jax = jax
        self._api = api
        self._lm = lm
        self._paged = paged
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self.page_size = page_size
        self.pages_per_slot = cache_len // page_size
        # parked requests keep their pages resident while their old slot
        # re-admits someone else, so the pool carries slack beyond
        # n_slots * pages_per_slot; ``slack_slots`` sizes it (default: one
        # extra fleet's worth — parked work is bounded by live requests)
        self.slack_slots = slack_slots
        # ``hbm_bytes`` replaces the slack heuristic with the ledger: the
        # pool holds exactly what the per-shard HBM byte budget buys
        # (capacity == hbm_bytes // page bytes; the trash page rides on
        # top — it is pool bookkeeping, not budgeted KV).  Parked pages
        # stay resident in the pool, so on a budget-sized pool parked KV
        # competes for the same real bytes the admission ledger governs —
        # physical, unlike slack sizing, which quietly granted parked
        # requests a second fleet's worth of HBM.
        self.hbm_bytes = hbm_bytes
        self.page_bytes = paged.kv_page_bytes(cfg, page_size)
        self.use_kernel = use_kernel
        self._decode = jax.jit(api.make_paged_decode_fn(cfg, use_kernel))
        self._prefill = api.make_prefill_fn(cfg, cache_len)
        self._dense_axes = api.batch_axis_spec(
            lambda n: lm.init_state(cfg, n, cache_len))
        self._paged_axes = api.batch_axis_spec(
            lambda n: paged.init_paged_state(cfg, n, 4, page_size))
        self.stats = {"pool_page_writes": 0, "pool_copies": 0,
                      "table_splices": 0}

    # -- pool bookkeeping (host-side metadata) --------------------------------
    def init(self, n_slots: int) -> tuple:
        if self.hbm_bytes is not None and self.page_bytes > 0:
            # ledger-sized pool: capacity is what the byte budget buys
            num_pages = 1 + int(self.hbm_bytes) // self.page_bytes
            assert num_pages > 1, \
                f"hbm_bytes={self.hbm_bytes} buys no page " \
                f"(page_bytes={self.page_bytes})"
        else:
            slack = n_slots if self.slack_slots is None else self.slack_slots
            num_pages = 1 + (n_slots + slack) * self.pages_per_slot
        shard = _PagedShard(
            states=self._paged.init_paged_state(
                self.cfg, n_slots, num_pages, self.page_size),
            table=np.zeros((n_slots, self.pages_per_slot), np.int32),
            lengths=np.zeros((n_slots,), np.int32),
            free=list(range(1, num_pages)),
            slot_pages=[[] for _ in range(n_slots)])
        return shard, np.zeros((n_slots, 1), np.int32)

    def _alloc(self, shard: _PagedShard, n: int) -> list[int]:
        if len(shard.free) < n:
            raise RuntimeError(
                f"KV page pool exhausted ({n} pages requested, "
                f"{len(shard.free)} free): raise slack_slots or cache_len")
        pages, shard.free = shard.free[:n], shard.free[n:]
        return pages

    def _ensure_pages(self, shard: _PagedShard) -> None:
        """Lazy page allocation: before a decode call, any occupied slot
        whose next write position crosses into an unmapped page gets one
        from the free list — the vLLM-style on-demand grow that keeps a
        short request from reserving its worst-case KV upfront."""
        for b, pages in enumerate(shard.slot_pages):
            if not pages:
                continue                      # free slot: decodes into trash
            pi = int(shard.lengths[b]) // self.page_size
            if pi >= self.pages_per_slot:
                raise RuntimeError(
                    f"slot {b} reached cache_len={self.cache_len}: the "
                    f"engine admitted prompt+decode longer than the cache")
            if shard.table[b, pi] == 0:
                (pg,) = self._alloc(shard, 1)
                shard.table[b, pi] = pg
                pages.append(pg)

    # -- handles --------------------------------------------------------------
    def _fresh_handle(self, dense_states, i: int, length: int) -> dict:
        """One prefilled sequence, sliced out of a (possibly batched)
        dense prefill: attention K/V kept dense per layer (paged in at
        splice), every other state leaf sliced on its batch axis."""
        lax = self._jax.lax
        kv, leaves = {}, {}
        for si, (pat, _) in enumerate(self._lm._stages(self.cfg)):
            for pi, kind in enumerate(pat):
                st = dense_states[si][pi]
                if kind == "attn":
                    # KVCache k/v are (reps, B, C, K, hd); the prompt's
                    # tokens sit at positions [0, length) — ring-free as
                    # long as length <= C, asserted at prefill
                    kv[(si, pi)] = (st.k[:, i, :length], st.v[:, i, :length])
                else:
                    leaves[(si, pi)] = self._jax.tree.map(
                        lambda ax, b: b if ax < 0
                        else lax.index_in_dim(b, i, ax, keepdims=True),
                        self._dense_axes[si][pi], st)
        return {"kind": "fresh", "length": length, "kv": kv,
                "leaves": leaves}

    def prefill(self, prompt: np.ndarray) -> tuple[int, object]:
        return self.prefill_wave([prompt])[0]

    def prefill_wave(self, prompts: list) -> list:
        jnp = self._jax.numpy
        S = len(prompts[0])
        C = self._lm._cache_len(self.cfg, self.cache_len)
        assert S <= C, \
            f"paged prefill keeps the whole prompt resident ({S} > {C})"
        logits, st = self._prefill(self.params,
                                   {"tokens": jnp.asarray(np.stack(prompts))})
        toks = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        return [(int(toks[i]), self._fresh_handle(st, i, S))
                for i in range(len(prompts))]

    # -- decode ---------------------------------------------------------------
    def decode(self, tokens: np.ndarray, shard: _PagedShard
               ) -> tuple[np.ndarray, object]:
        jnp = self._jax.numpy
        self._ensure_pages(shard)
        logits, shard.states = self._decode(
            self.params, jnp.asarray(tokens), shard.states,
            jnp.asarray(shard.table), jnp.asarray(shard.lengths))
        # every slot's position advances, occupied or not — the host-side
        # mirror of the dense path's ``pos + 1`` for the whole batch
        shard.lengths = shard.lengths + 1
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        return next_tok, shard

    # -- splice / extract: migration as metadata ------------------------------
    def splice(self, shard: _PagedShard, pairs: list[tuple[int, object]]):
        jnp = self._jax.numpy
        pool_pages: dict[tuple, list] = {}    # (si,pi) -> [(pages, k, v)]
        leaf_writes: dict[tuple, list] = {}   # (si,pi) -> [(slot, tree)]
        ps = self.page_size
        for slot, h in pairs:
            assert not shard.slot_pages[slot], \
                f"splice into slot {slot} which still owns pages"
            if h["kind"] == "fresh":
                pages: list[int] = []
                if h["kv"]:  # attention-free models own no pages
                    npg = -(-h["length"] // ps)
                    pages = self._alloc(shard, npg)
                    for (si, pi), (k, v) in h["kv"].items():
                        pad = [(0, 0), (0, npg * ps - h["length"]),
                               (0, 0), (0, 0)]
                        kp = jnp.pad(k, pad).reshape(
                            k.shape[0], npg, ps, *k.shape[2:])
                        vp = jnp.pad(v, pad).reshape(
                            v.shape[0], npg, ps, *v.shape[2:])
                        pool_pages.setdefault((si, pi), []).append(
                            (pages, kp, vp))
                    self.stats["pool_page_writes"] += npg
            else:                              # parked paged handle
                src: _PagedShard = h.pop("shard")
                pages = h.pop("pages")
                if src is shard or not pages:
                    # same pool: the migration IS the metadata write
                    self.stats["table_splices"] += 1
                else:
                    # cross-shard (a DCN move between host batches): the
                    # one place pages physically move — copy them between
                    # pools, then free the source's
                    dst = self._alloc(shard, len(pages))
                    src_idx = jnp.asarray(pages)
                    dst_idx = jnp.asarray(dst)
                    for si, (pat, _) in enumerate(
                            self._lm._stages(self.cfg)):
                        new_stage = list(shard.states[si])
                        for pi, kind in enumerate(pat):
                            if kind != "attn":
                                continue
                            pool = shard.states[si][pi]
                            spool = src.states[si][pi]
                            new_stage[pi] = self._paged.PagedKV(
                                k=pool.k.at[:, dst_idx].set(
                                    spool.k[:, src_idx]),
                                v=pool.v.at[:, dst_idx].set(
                                    spool.v[:, src_idx]))
                        shard.states[si] = tuple(new_stage)
                    src.free.extend(pages)
                    self.stats["pool_copies"] += len(pages)
                    pages = dst
            shard.slot_pages[slot] = list(pages)
            shard.table[slot, :] = 0
            shard.table[slot, :len(pages)] = pages
            shard.lengths[slot] = h["length"]
            for key, tree in h["leaves"].items():
                leaf_writes.setdefault(key, []).append((slot, tree))
        # apply the queued fresh-prefill page-ins: ONE scatter per layer
        for (si, pi), entries in pool_pages.items():
            pool = shard.states[si][pi]
            idx = jnp.asarray([p for pages, _, _ in entries for p in pages])
            kcat = jnp.concatenate([k for _, k, _ in entries], axis=1)
            vcat = jnp.concatenate([v for _, _, v in entries], axis=1)
            new_stage = list(shard.states[si])
            new_stage[pi] = self._paged.PagedKV(
                k=pool.k.at[:, idx].set(kcat.astype(pool.k.dtype)),
                v=pool.v.at[:, idx].set(vcat.astype(pool.v.dtype)))
            shard.states[si] = tuple(new_stage)
        # batch-axis leaves (recurrent states): one traversal per layer
        for (si, pi), entries in leaf_writes.items():
            slots = jnp.asarray([s for s, _ in entries])

            def write(ax, b, *ones):
                if ax < 0:
                    return b
                idx = (slice(None),) * ax + (slots,)
                return b.at[idx].set(jnp.concatenate(ones, axis=ax))

            new_stage = list(shard.states[si])
            new_stage[pi] = self._jax.tree.map(
                write, self._paged_axes[si][pi], shard.states[si][pi],
                *[t for _, t in entries])
            shard.states[si] = tuple(new_stage)
        return shard

    def extract(self, shard: _PagedShard, slot: int):
        """Park one slot: hand its pages to the caller (ownership moves
        with the handle — ``release`` is NOT called on parked pages) and
        zero its table row, so the freed slot's ongoing trash decode
        cannot touch the parked KV."""
        lax = self._jax.lax
        leaves = {}
        for si, (pat, _) in enumerate(self._lm._stages(self.cfg)):
            for pi, kind in enumerate(pat):
                if kind == "attn":
                    continue
                leaves[(si, pi)] = self._jax.tree.map(
                    lambda ax, b: b if ax < 0
                    else lax.index_in_dim(b, slot, ax, keepdims=True),
                    self._paged_axes[si][pi], shard.states[si][pi])
        handle = {"kind": "paged", "shard": shard,
                  "pages": shard.slot_pages[slot],
                  "length": int(shard.lengths[slot]), "leaves": leaves}
        shard.slot_pages[slot] = []
        shard.table[slot, :] = 0
        shard.lengths[slot] = 0
        return handle

    def release(self, shard: _PagedShard, slot: int):
        """Free a finished slot's pages back to the pool (the engine's
        ``_evict`` hook).  Parked slots were already emptied by
        ``extract`` — this is then a no-op."""
        shard.free.extend(shard.slot_pages[slot])
        shard.slot_pages[slot] = []
        shard.table[slot, :] = 0
        shard.lengths[slot] = 0
        return shard

    def drop(self, handle) -> None:
        """Free a *parked* handle's pages back to their source pool
        without ever splicing it in — stale-session eviction: the engine
        lets go of a sleeping session's KV to reclaim the pages, and a
        later wake rebuilds the continuation by re-prefill.  Fresh
        (never-paged) handles own no pool pages and are a no-op."""
        if not isinstance(handle, dict) or handle.get("kind") != "paged":
            return
        src = handle.get("shard")
        pages = handle.get("pages") or []
        if src is not None and pages:
            src.free.extend(pages)
        handle["pages"] = []


class StubModelBackend:
    """Deterministic numpy decode/prefill stand-in — no jax, no jit.

    Each slot's "KV state" is ``(position, history_hash)``; the next token
    is a function of the full token history, so any KV mishandling (a lost
    splice, a stale slot, a wrong-slot write) changes the output stream and
    is caught by equality tests.  This is what tests and the CI serving
    benchmark run: the scheduler stack is identical, only the model is
    stubbed."""

    M = 2_147_483_647                 # hash modulus (prime, fits int64)

    def __init__(self, vocab: int = 251):
        self.vocab = vocab

    def init(self, n_slots: int) -> tuple[np.ndarray, np.ndarray]:
        return (np.zeros((n_slots, 2), np.int64),
                np.zeros((n_slots, 1), np.int32))

    def _fold(self, acc: int, tok: int) -> int:
        return (acc * 31 + int(tok) + 1) % self.M

    def prefill(self, prompt: np.ndarray) -> tuple[int, np.ndarray]:
        acc = 0
        for tok in np.asarray(prompt).ravel():
            acc = self._fold(acc, tok)
        return acc % self.vocab, np.array([len(prompt), acc], np.int64)

    def prefill_wave(self, prompts: list) -> list:
        """Vectorised same-length prefill: fold all rows column by column.

        Exact-equal to per-request :meth:`prefill` (the fold stays inside
        int64: acc < 2^31, so acc*31 + tok fits with room to spare) —
        wave batching must never change a stream."""
        arr = np.asarray(np.stack(prompts), np.int64)          # (B, S)
        acc = np.zeros(len(arr), np.int64)
        for j in range(arr.shape[1]):
            acc = (acc * 31 + arr[:, j] + 1) % self.M
        return [(int(a % self.vocab), np.array([arr.shape[1], a], np.int64))
                for a in acc]

    def decode(self, tokens: np.ndarray, states: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
        acc = (states[:, 1] * 31 + tokens[:, 0].astype(np.int64) + 1) % self.M
        out = np.stack([states[:, 0] + 1, acc], axis=1)
        return (acc % self.vocab).astype(np.int32), out

    def splice(self, states: np.ndarray, pairs: list[tuple[int, np.ndarray]]
               ) -> np.ndarray:
        states = states.copy()
        for slot, row in pairs:
            states[slot] = row
        return states

    def extract(self, states: np.ndarray, slot: int) -> np.ndarray:
        return states[slot].copy()

    def peek(self, states: np.ndarray, slot: int) -> np.ndarray:
        """Non-mutating snapshot read (same as extract for this backend)."""
        return states[slot].copy()

    def replay(self, state: np.ndarray, tokens) -> np.ndarray:
        """Teacher-forced advance of one saved state through known output
        tokens — the checkpoint-restore fast path: a snapshot taken after
        m' emitted tokens plus a replay of tokens m'..m-1 reproduces the
        live state after m tokens exactly (decode is the same fold)."""
        pos, acc = int(state[0]), int(state[1])
        for tok in np.asarray(tokens, np.int64).ravel():
            acc = self._fold(acc, tok)
            pos += 1
        return np.array([pos, acc], np.int64)


# ---------------------------------------------------------------------------
# agentic sessions: the sleeping ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SleepEntry:
    """One session blocked on an external event (a tool response).

    In sleep-and-release mode the entry lives in the engine's
    :class:`SleepingLedger` and the thread is held off every run queue;
    in the hold-the-slot baseline the same record sits in
    ``ServingEngine._thinking`` keyed by the slot it refuses to give up.
    Either way ``state`` is the parked backend KV handle (``None`` once a
    stale eviction dropped it), ``token`` the last emitted token the
    resumed decode feeds on, and ``home_page`` the page-group component
    the session slept under — the anchor of the wake-affinity quote."""

    rid: int
    thread: Thread
    state: object
    token: int
    home_page: object
    slept_step: int
    wake_at: Optional[int]            # None: waits for engine.wake(rid)
    retained: Optional[int] = None    # page-group index still holding the
                                      # session's HBM reservation
                                      # (``sleep_retain_hbm``)


class SleepingLedger:
    """rid-keyed registry of sessions asleep on external events.

    Deliberately dumb — add/get/pop plus the two scans the engine's
    per-step wake pass runs: ``due`` (tool responses that have landed)
    and ``stale`` (KV parked longer than the session TTL, still worth
    holding a handle for).  The engine is not drained while any entry
    exists: a sleeping session owns no slot and sits on no queue, and
    this ledger is the only thing keeping it alive."""

    def __init__(self) -> None:
        self._by_rid: dict[int, SleepEntry] = {}

    def add(self, e: SleepEntry) -> None:
        assert e.rid not in self._by_rid, f"rid {e.rid} already asleep"
        self._by_rid[e.rid] = e

    def get(self, rid: int) -> Optional[SleepEntry]:
        return self._by_rid.get(rid)

    def pop(self, rid: int) -> SleepEntry:
        return self._by_rid.pop(rid)

    def __len__(self) -> int:
        return len(self._by_rid)

    def __contains__(self, rid: int) -> bool:
        return rid in self._by_rid

    def entries(self) -> list[SleepEntry]:
        return list(self._by_rid.values())

    def due(self, now: float) -> list[SleepEntry]:
        """Entries whose scheduled tool response has landed."""
        return [e for e in self._by_rid.values()
                if e.wake_at is not None and e.wake_at <= now]

    def stale(self, now: float, ttl: int) -> list[SleepEntry]:
        """Entries still holding KV that have slept past the TTL."""
        return [e for e in self._by_rid.values()
                if e.state is not None and now - e.slept_step >= ttl]


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServingEngine:
    """Continuous batching driven by the shared scheduler runtime.

    * a gang (bubble) bursts only when enough slots are free to co-schedule
      it (priorities implement the paper's gang scheduling — Figure 1);
    * prefix-affine requests land in adjacent slots so their shared KV
      prefix stays resident (the data-sharing relation);
    * a starving slot's ``acquire`` runs the hierarchical steal pass — a
      queued gang is pulled whole from a loaded page group, its threads
      flagged for next-touch so the first post-migration admission re-homes
      their KV (batched splice), and the thief pays the cost model's
      admission-latency bill;
    * page-group queue-depth skew feeds the runtime's cost-benefit test and
      triggers one bulk ``rebalance`` when recent steal spend exceeds the
      re-spread bill;
    * a request group that stalls (client backpressure) is *regenerated*:
      pulled out of the slots — its per-slot KV parked — and re-queued as a
      closed bubble, keeping its affinity;
    * with ``pods``/``hosts`` > 1 the slot hierarchy is sharded across
      hosts: steals cross the DCN when nothing nearer has work, priced by
      the cost model's per-level table (``bill_model`` splits what the
      scheduler *believes* a crossing costs from what it *pays* — the
      DCN-naive baseline ranks victims flat and pays real DCN latency);
    * with ``hbm_budget`` set, each KV page group carries a byte budget
      (``kv_bytes`` per resident request): admission skips slots of a full
      group (the gang parks on its queue instead of thrashing), the steal
      survey and the rebalance deal refuse destinations that cannot hold
      the loot, and the ledger in ``hbm_used`` never exceeds a group's
      budget.  ``capacity_aware=False`` keeps the budget enforced but
      discovers fullness only after the claim — loot is dragged (and its
      steal billed) before bouncing back: the measurable capacity-blind
      baseline for ``serve/hbm_pressure_refusal_speedup``;
    * **execution is host-sharded** (``per_host_decode``, default on):
      each host drives its own decode batch — one ``decode_step`` per host
      per engine step over that host's KV shard, skipped when the host's
      batch is empty — and same-length fresh prompts admitted in one wave
      are prefilled in one ``prefill_wave`` call per host
      (``wave_prefill``, default on).  Neither changes a single decoded
      token (slots are independent; property-tested), they change what
      the engine *models*: per-shard step latency and per-host occupancy
      skew (``EngineStats.host_decode_steps`` / ``host_active_slots``)
      instead of one fleet-wide batch no real DCN-sharded deployment
      runs;
    * **rebalancing is DCN-priced** (``dcn_rebalance``, default on): each
      re-spread move is billed by the boundary it crosses through the
      cost model's ``level_table`` (a cross-host move pays the DCN toll,
      not flat ``rebalance_per_move``), and the queue-depth trigger
      compares a machine-wide re-spread against **host-local** ones
      (`BubbleScheduler.rebalance(scope=)`), buying the local page
      shuffle whenever the machine-wide quote is dearer.
      ``dcn_rebalance=False`` keeps the flat-priced, machine-wide-only
      trigger — the measurable baseline for
      ``serve/dcn_rebalance_speedup``.  Single-host fleets have no tabled
      boundary, so both settings are byte-identical there.

    ``mode="admission"`` is the pre-runtime engine: plain admission, no
    steal, no rebalance, first-touch homing.

    Knob units, for the record: every cost-model price is in **engine
    steps** (admission latency); ``hbm_budget``/``kv_bytes`` are in the
    same abstract bytes as each other (only their ratio matters — the
    resident-request count a page group can hold); ``window``/``cooldown``
    are engine steps, ``depth_skew``/``min_backlog`` are queued decode
    threads.
    """

    def __init__(self, cfg, params, *, n_slots: int = 8,
                 cache_len: int = 256, group: int = 4,
                 hosts: int = 1, pods: int = 1,
                 backend=None, mode: str = "runtime",
                 cost_model: StealCostModel = SERVE_COST,
                 bill_model: Optional[StealCostModel] = None,
                 hbm_budget: Optional[float] = None, kv_bytes: float = 1.0,
                 capacity_aware: bool = True,
                 per_host_decode: bool = True, wave_prefill: bool = True,
                 dcn_rebalance: bool = True,
                 host_speed=None, speed_aware: bool = True,
                 gang_split: bool = False,
                 depth_skew: int = 2, window: int = 16,
                 min_backlog: int = 2, cooldown: Optional[int] = None,
                 sla_classes: Optional[dict] = None, preempt: bool = False,
                 preempt_cooldown: int = 8,
                 kv_store=None, kv_restore_level: str = "host",
                 reprefill_unit: float = 0.25,
                 agentic_sleep: bool = True, wake_quote: bool = True,
                 sleep_retain_hbm: bool = False,
                 session_ttl: Optional[int] = None):
        assert mode in ("runtime", "admission"), mode
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.mode = mode
        self.topo = slots_topology(n_slots, group, hosts=hosts, pods=pods)
        if mode == "runtime":
            self.policy = StealPolicy(self.topo, cost_model=cost_model,
                                      bill_model=bill_model)
        else:
            self.policy = BubblePolicy(self.topo, steal=False)
        self.sched = self.policy.sched
        # -- per-page-group HBM ledger (admission control) --
        assert hbm_budget is None or hbm_budget >= kv_bytes, \
            "a page group must hold at least one request's KV"
        self.hbm_budget = hbm_budget
        self.kv_bytes = kv_bytes
        names = self.topo.level_names()
        self._page_idx = names.index("page")
        self._host_idx = names.index("host") if "host" in names else None
        # slot -> global page-group index (its ancestor at the page level)
        self._page_of = [self.topo.cpus[s].path()[self._page_idx].index
                         for s in range(n_slots)]
        # page-group index -> owning host component (None on single host):
        # the rebalance trigger uses it to spot skew that is host-local
        self._page_host = [
            p.path()[self._host_idx] if self._host_idx is not None else None
            for p in self.topo.components("page")]
        self.hbm_used = [0.0] * len(self.topo.components("page"))
        self._slot_charged = [False] * n_slots   # slot holds a reservation
        self.capacity_aware = capacity_aware and hbm_budget is not None
        # -- straggler model: per-host relative decode speed in (0, 1] --
        # ``host_speed[h]`` < 1 makes host h's decode_step span more than
        # one engine step (a speed-credit accumulator in :meth:`step`);
        # ``speed_aware`` additionally lets the scheduler SEE the skew
        # (the costed steal survey and the LPT rebalance deal weigh
        # backlog by host speed through ``speed_of``).  ``speed_aware=
        # False`` with a nonzero skew is the lockstep-assuming baseline:
        # the machine still runs slow, the scheduler still deals to it.
        n_hosts_total = (len(self.topo.components("host"))
                         if self._host_idx is not None else 1)
        if host_speed is not None:
            host_speed = [float(s) for s in host_speed]
            assert len(host_speed) == n_hosts_total, \
                f"host_speed needs one entry per host " \
                f"({len(host_speed)} != {n_hosts_total})"
            assert all(0.0 < s <= 1.0 for s in host_speed), host_speed
            assert per_host_decode or self._host_idx is None, \
                "host_speed on a multi-host fleet needs per_host_decode"
        self.host_speed = host_speed
        self.speed_aware = speed_aware and host_speed is not None
        self._speed_by_host = (
            {id(h): s for h, s in zip(self.topo.components("host"),
                                      host_speed)}
            if host_speed is not None and self._host_idx is not None else {})
        self.gang_split = gang_split
        self.runtime = SchedulerRuntime(
            self.topo, self.policy, on_data_migrate=self._on_kv_migrate,
            can_accept=(self._can_accept
                        if self.capacity_aware and mode == "runtime"
                        else None),
            bytes_of=(self._kv_need if mode == "runtime" else None),
            speed_of=(self._host_speed_of
                      if self.speed_aware and mode == "runtime" else None))
        # this engine bills a rebalance's level-table tolls where the KV
        # lands (admission freezes on the receiving page groups, see
        # _maybe_rebalance), so opt into the scheduler's split billing —
        # consume_cost() then returns the flat trigger-side part only
        self.sched.ingest_billing = True
        self.backend = backend if backend is not None else \
            JaxModelBackend(cfg, params, cache_len)
        # -- host-sharded execution: one decode batch (one backend state
        # shard, one decode_step per engine step) per execution group.
        # With per_host_decode on a multi-host fleet the groups are the
        # hosts' (contiguous) slot ranges; otherwise one group spans the
        # whole fleet — the historical global batch, byte for byte.
        self.per_host_decode = per_host_decode
        self.wave_prefill = wave_prefill
        self.dcn_rebalance = dcn_rebalance
        if per_host_decode and self._host_idx is not None:
            ranges = []
            for h in self.topo.components("host"):
                cpus = [leaf.cpu for leaf in h.leaves()]
                assert cpus == list(range(cpus[0], cpus[-1] + 1)), cpus
                ranges.append((cpus[0], cpus[-1] + 1))
            self._exec_groups = ranges
        else:
            self._exec_groups = [(0, n_slots)]
        self._group_of = [g for g, (lo, hi) in enumerate(self._exec_groups)
                          for _ in range(lo, hi)]   # slot -> exec group
        # per-exec-group decode speed + the speed-credit accumulator: a
        # group decodes when its credit reaches one whole step.  Exec
        # groups are the hosts' slot ranges in host-component order
        # (asserted above when host_speed is given), so index g maps 1:1.
        if host_speed is None:
            self._group_speed = [1.0] * len(self._exec_groups)
        elif len(self._exec_groups) == len(host_speed):
            self._group_speed = list(host_speed)
        else:                        # single exec group (single host)
            self._group_speed = [host_speed[0]]
        self._host_credit = [0.0] * len(self._exec_groups)
        self._states = []
        tok_shards = []
        for lo, hi in self._exec_groups:
            st, tok = self.backend.init(hi - lo)
            self._states.append(st)
            tok_shards.append(tok)
        self.tokens = tok_shards[0] if len(tok_shards) == 1 else \
            np.concatenate(tok_shards, axis=0)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_thread: dict[int, Thread] = {}
        self._reqs: dict[int, Request] = {}
        self._gangs: dict[str, Bubble] = {}
        self._next_rid = 0
        self._kv_park: dict[int, tuple[object, int]] = {}  # rid -> (state, tok)
        self._stall = [0.0] * n_slots     # admission-latency bill per slot
        self._pending: dict[int, Thread] = {}  # claimed, waiting out a stall
        # queue-depth rebalance trigger state (runtime mode only)
        self.depth_skew = depth_skew
        self.min_backlog = min_backlog
        self.window = window
        self.cooldown = window if cooldown is None else cooldown
        self._paid: deque[float] = deque()        # steal cost per step
        self._steps_since_rebalance = self.cooldown   # start armed
        self._cost_mark = 0.0
        # -- SLA tiers (open-loop traffic) --
        # ``sla_classes`` maps class name -> :class:`~repro.serving.
        # workload.SLAClass`; set, it turns on the weighted-deficit
        # round-robin admission gate (a task filter over the covering-list
        # walk), multilevel-feedback demotion, and — with ``preempt`` —
        # KV park/splice preemption of preemptible tiers under
        # ``preempts``-class backlog.  ``None`` (default) is the
        # historical class-blind engine, bit for bit.
        self.sla_classes = dict(sla_classes) if sla_classes else None
        self.preempt = preempt and self.sla_classes is not None
        self.preempt_cooldown = preempt_cooldown
        self._last_preempt = -(10 ** 9)
        # WDRR deficit ledger: classes start with one quantum of credit
        self._wdrr_credit = ({n: float(c.weight)
                              for n, c in self.sla_classes.items()}
                             if self.sla_classes else {})
        # latency ledgers, keyed by CONTRACT class (``Request.sla``;
        # ``None``-classed requests land under "unclassed")
        self._ttft: dict[str, list] = {}
        self._gaps: dict[str, list] = {}
        # -- elastic fleet: KV continuation snapshots + live kill/join --
        # ``kv_store`` is a :class:`~repro.checkpoint.kv_store.KVStore`
        # (duck-typed: due/maybe_snapshot/restore); on its cadence the
        # engine snapshots every resident continuation.  When a host dies
        # (:meth:`kill_host`) each orphan is restored from the snapshot —
        # a ``kv_restore_level`` boundary toll on its KV bytes plus a
        # replay of the tokens emitted since, at ``reprefill_unit`` steps
        # per token — or re-prefilled from scratch (full history at the
        # same per-token rate), whichever the cost model quotes cheaper.
        self.kv_store = kv_store
        self.kv_restore_level = kv_restore_level
        self.reprefill_unit = reprefill_unit
        # -- agentic sessions: tool-call sleep/wake --
        # ``agentic_sleep`` (default): a request hitting a tool-call
        # marker *sleeps* — KV parked, slot freed, thread held in the
        # SleepingLedger until the tool response.  ``False`` is the
        # hold-the-slot baseline: the request keeps its slot (and HBM
        # reservation) idle through the think gap — the measurable
        # contrast for ``serve/agentic_slot_util_speedup``.  Streams are
        # identical either way: a sleep injects no tokens.
        self.agentic_sleep = agentic_sleep
        # ``wake_quote`` arbitrates wake placement (home page group vs
        # the cheapest group under current queue/HBM pressure, the away
        # move priced at cost-model belief and billed at bill-model
        # truth); ``False`` pins every wake to its home group.
        self.wake_quote = wake_quote
        # ``sleep_retain_hbm``: keep the sleeper's KV bytes reserved in
        # its home page group (guaranteed wake-home capacity, paid in
        # admission headroom); default refunds the reservation — parked
        # KV lives host-side, off the budget, like every other park.
        self.sleep_retain_hbm = sleep_retain_hbm
        # ``session_ttl``: engine steps a sleeping session's KV survives
        # before the stale-eviction pass drops it (the wake then pays a
        # full re-prefill).  ``None`` holds KV forever.
        self.session_ttl = session_ttl
        self._sleeping = SleepingLedger()
        self._thinking: dict[int, SleepEntry] = {}   # hold-mode, by slot
        self._wake_lat: dict[str, list] = {}         # wake-to-token ledger
        if kv_store is not None:
            assert mode == "runtime", "kv snapshots need the runtime engine"
            assert callable(getattr(self.backend, "peek", None)), \
                "kv_store needs a backend with a non-mutating peek() " \
                "(the paged backend's extract is a destructive table edit)"
        self._dead_slots: set[int] = set()    # cpu ids of killed hosts
        self._restore_debt: dict[int, float] = {}   # rid -> admission bill
        self._group = group                   # page-group size, for joins
        self._host_group = ({id(h): g for g, h in
                             enumerate(self.topo.components("host"))}
                            if self._host_idx is not None else {})
        self.stats = EngineStats(
            host_decode_steps=[0] * len(self._exec_groups),
            host_active_slots=[0] * len(self._exec_groups),
            host_skipped_steps=[0] * len(self._exec_groups))
        self.steps = 0
        self.completed: list[Request] = []

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               prio: Optional[int] = None, gang: Optional[str] = None,
               home: Optional[str] = None, sla: Optional[str] = None,
               tool_calls: tuple = ()) -> int:
        """Queue one request.  ``home`` names a topology component
        (``"host1"``, ``"page3"``, ...) whose list receives the work — the
        cross-host admission path: a front-end that routes a gang to one
        shard wakes its bubble there, narrowing its scheduling area to
        that subtree; other shards can still reach it, but only by paying
        the steal survey's (DCN-priced) bill.  ``None`` keeps the global
        list (any slot may admit it).  A late joiner to an already-burst
        gang honors its own ``home`` (it lands on that list) and falls
        back to the gang's burst list otherwise — ``home`` always wins
        over where the gang happened to burst.

        ``sla`` labels the request with an SLA class.  On an engine built
        with ``sla_classes`` the class also *schedules*: ``prio`` defaults
        to the class's paper priority (§3.3.2) and the class rides the
        WDRR admission gate; without ``sla_classes`` the label is carried
        for measurement only (the FIFO baseline's requests are judged by
        the same SLOs).

        ``tool_calls`` marks the request agentic: a tuple of
        ``(at_tokens, think_steps)`` markers, ordered by position — when
        the emitted-token count reaches ``at_tokens`` the request blocks
        on a tool response for ``think_steps`` engine steps
        (``think_steps=None`` blocks until :meth:`wake`).  See
        ``agentic_sleep`` for what blocking does to the slot."""
        tool_calls = tuple((int(at), None if think is None else int(think))
                           for at, think in tool_calls)
        last_at = 1
        for at, think in tool_calls:
            assert 1 <= at < max_new_tokens, \
                f"tool call at token {at} outside 1..{max_new_tokens - 1}"
            assert at >= last_at, "tool calls must be ordered by position"
            assert think is None or think >= 1, think
            last_at = at
        if prio is None:
            prio = (self.sla_classes[sla].prio
                    if self.sla_classes and sla in self.sla_classes else 0)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                      prio=prio, gang=gang, sla=sla, tier=sla,
                      submit_step=self.steps, tool_calls=tool_calls)
        self._reqs[rid] = req
        t = thread(float(max_new_tokens), name=f"req{rid}", prio=prio,
                   data=gang or f"req{rid}")
        t.request = req                                   # type: ignore
        at = self._home_queue(home)
        if gang is None:
            if at is None:
                self.sched.submit_thread(t)
            else:
                at.push(t)
            return rid
        g = self._gang_bubble(gang, prio)
        g.insert(t)
        if g.burst:
            # the gang already burst: late joiners must land on a live
            # list (inserting into an off-queue burst husk would strand
            # them forever).  The caller's ``home`` wins — the old code
            # silently dropped it and pinned the joiner to the burst
            # list — falling back to the gang's scheduling area
            q = at if at is not None else (
                g.home_list if g.home_list is not None
                else self.sched.queues.global_queue())
            q.push(t)
        elif not self._gang_scheduled(g):
            # fresh gang, or one that completed/was dropped and has new
            # members: (re-)wake it.  The old engine set a sticky ``_woken``
            # flag here, so a finished gang's bubble could never be woken
            # again and later submits to the same gang were lost.
            self.sched.wake_up_bubble(g, at=at)
        elif not self._bubble_queued(g):
            # the gang is live but only through its *members* (a rebalance
            # expanded the closed bubble and dealt them out individually,
            # or they occupy slots) — the bubble itself sits on no list and
            # nothing will ever burst it, so a thread left only inside it
            # is stranded: schedule the late joiner directly, like its
            # expanded siblings — again honoring the caller's ``home``
            q = at if at is not None else (
                g.home_list if g.home_list is not None
                else self.sched.queues.global_queue())
            q.push(t)
        return rid

    def _bubble_queued(self, g: Bubble) -> bool:
        """Whether the bubble object itself sits on some run queue (its
        members being queued individually does not count)."""
        return any(task is g for q in self.sched.queues.queues.values()
                   for task in q.tasks)

    def _home_queue(self, home: Optional[str]):
        """Resolve a component name to its run queue (None = global).

        Submit is the admission hot path, so the name->queue map is built
        once per engine (component names are unique: ``level.name`` +
        index)."""
        if home is None:
            return None
        by_name = getattr(self, "_queues_by_name", None)
        if by_name is None:
            by_name = {q.comp.name: q
                       for q in self.sched.queues.queues.values()}
            self._queues_by_name = by_name
        try:
            return by_name[home]
        except KeyError:
            raise ValueError(f"unknown home component {home!r} "
                             f"(topology: {self.topo.describe()})") from None

    def _gang_bubble(self, gang: str, prio: int) -> Bubble:
        key = f"gang:{gang}"
        b = self._gangs.get(key)
        if b is None:
            # gang bubbles less prioritised than their threads => they burst
            # only when running threads can't fill the slots (Figure 1)
            b = bubble(name=key, prio=prio - 1, burst_level="page")
            self._gangs[key] = b
        return b

    def _gang_scheduled(self, g: Bubble) -> bool:
        """Whether the scheduler still owns the gang: the closed bubble (or
        any of its tasks) sits on some list, or a member occupies a slot."""
        for q in self.sched.queues.queues.values():
            for task in q.tasks:
                if task is g or task.root() is g:
                    return True
        return any(t.parent is g for t in self.slot_thread.values()) or \
            any(t.parent is g for t in self._pending.values())

    # -- KV homing (the data policy's physical side) --------------------------
    def _on_kv_migrate(self, data: str, old_slot: int, new_slot: int) -> None:
        self.stats.kv_migrations += 1
        names = self.topo.level_names()
        common = names.index(self.topo.common_level(old_slot, new_slot).name)
        if common < self._page_idx:
            self.stats.kv_page_moves += 1      # crossed KV page groups
        if self._host_idx is not None and common < self._host_idx:
            self.stats.kv_host_moves += 1      # crossed hosts: DCN traffic

    # -- the per-page-group HBM ledger (admission control) ---------------------
    def _headroom(self, page: int) -> float:
        """Unreserved HBM bytes left in one page group's budget."""
        if self.hbm_budget is None:
            return float("inf")
        return self.hbm_budget - self.hbm_used[page]

    def _charge(self, slot: int) -> None:
        """Reserve one request's KV bytes in the slot's page group — at
        *claim* time, so a stolen thread waiting out its admission stall in
        ``_pending`` cannot be overcommitted by later claims."""
        if not self._slot_charged[slot]:
            self.hbm_used[self._page_of[slot]] += self.kv_bytes
            self._slot_charged[slot] = True

    def _refund(self, slot: int) -> None:
        """Release the slot's reservation (request finished, parked, or
        folded back into a regenerated gang)."""
        if self._slot_charged[slot]:
            self.hbm_used[self._page_of[slot]] -= self.kv_bytes
            self._slot_charged[slot] = False

    def _kv_need(self, task) -> float:
        """KV bytes one task would occupy: whole gangs need room for every
        live member — stealing a gang a group cannot finish admitting
        would strand the tail."""
        if isinstance(task, Bubble):
            live = sum(1 for th in task.threads() if th.remaining > 0)
            return self.kv_bytes * max(live, 1)
        return self.kv_bytes

    def _host_speed_of(self, comp) -> float:
        """The scheduler's speed ruler: relative decode speed of the host
        owning ``comp`` (a page group, a slot, or the host list itself).
        Components above the host level — the machine-wide lists — have no
        one owner and run at nominal speed."""
        if not self._speed_by_host:
            return 1.0
        h = self.topo.ancestor_at(comp, "host")
        return self._speed_by_host[id(h)] if h is not None else 1.0

    def _can_accept(self, cpu: int, task, pending=()) -> bool:
        """The scheduler's capacity veto: can ``cpu``'s page group hold the
        loot's KV on top of what a bulk deal already routed there
        (``pending``)?  A full page group refuses and the survey/deal
        looks elsewhere."""
        need = self._kv_need(task) + sum(self._kv_need(p) for p in pending)
        return self._headroom(self._page_of[cpu]) >= need - 1e-9

    # -- SLA-class admission: weighted deficit round-robin --------------------
    @staticmethod
    def _live_thread(th) -> bool:
        """A queued thread that still has decoding to do (the opposite of
        a finished-gang husk awaiting collection)."""
        req = getattr(th, "request", None)
        return th.remaining > 0 and (req is None or not req.done)

    @staticmethod
    def _tier_of(th) -> Optional[str]:
        req = getattr(th, "request", None)
        return req.tier if req is not None else None

    def _queued_by_class(self) -> dict[str, int]:
        """Live queued decode threads per scheduling tier (slot-resident
        and ``_pending`` work is already admitted and does not count)."""
        counts = {n: 0 for n in self.sla_classes}
        for q in self.sched.queues.queues.values():
            for task in q.tasks:
                ths = task.threads() if isinstance(task, Bubble) else (task,)
                for th in ths:
                    if self._live_thread(th):
                        tier = self._tier_of(th)
                        if tier in counts:
                            counts[tier] += 1
        return counts

    def _wdrr_replenish(self, queued: set) -> None:
        """Start a new deficit round: every backlogged class earns its
        ``weight`` in credit (capped at 4x as a safety bound — credit is
        only ever granted when the whole round is spent, so in practice a
        class carries at most one quantum plus change)."""
        for n in queued:
            cls = self.sla_classes[n]
            self._wdrr_credit[n] = min(
                self._wdrr_credit.get(n, 0.0) + cls.weight,
                4.0 * cls.weight)

    def _wdrr_gate(self) -> Optional[set]:
        """One admission wave's deficit-round-robin bookkeeping.

        Classic DRR adapted to a priority walk: credit is replenished
        only when **every** backlogged class has spent its quantum (a new
        round) — NOT every wave, or a high-priority class spending at
        most the slot count per wave would re-earn it each time and the
        gate would degenerate to pure priority, starving ``batch``
        exactly the way the WDRR exists to prevent.  Between rounds a
        class out of credit is invisible to the covering-list walk, which
        is how lower tiers get their turn.  An idle class keeps at most
        one quantum (no banking a burst of credit to lock the batch
        later).  Returns the eligible-class set (backlogged AND holding
        >=1 credit; never empty while work is queued — the gate decides
        *whose* work goes first, never idles a slot), or ``None`` when no
        class has queued work."""
        counts = self._queued_by_class()
        queued = {n for n, c in counts.items() if c}
        for n, cls in self.sla_classes.items():
            if n not in queued:
                self._wdrr_credit[n] = min(self._wdrr_credit.get(n, 0.0),
                                           float(cls.weight))
        if not queued:
            return None
        elig = {n for n in queued if self._wdrr_credit[n] >= 1.0}
        if not elig:
            self._wdrr_replenish(queued)
            elig = {n for n in queued if self._wdrr_credit[n] >= 1.0}
        return elig if elig else set(queued)

    def _wdrr_filter(self, elig: set):
        """The task filter the eligible-class set puts on the covering-list
        walk.  Classless tasks always pass.  Stale husks (finished
        threads, empty or all-done bubbles) must ALSO pass: they carry no
        work to gate, and hiding them from the lookup would leave them
        stuck on their queues forever — ``_drained()`` would never see an
        empty machine.  The admit loop drops them on sight instead."""
        def ok(task) -> bool:
            if isinstance(task, Bubble):
                live = [th for th in task.threads() if self._live_thread(th)]
                if not live:
                    return True             # husk: keep it collectable
                return any(self._tier_of(th) is None
                           or self._tier_of(th) in elig for th in live)
            if not self._live_thread(task):
                return True                 # husk: keep it collectable
            tier = self._tier_of(task)
            return tier is None or tier in elig
        return ok

    def _wdrr_spend(self, t: Thread, elig: set) -> None:
        """Bill one admission against its class's deficit; a class out of
        credit leaves the eligible set, and when the last one does a new
        round replenishes every still-backlogged class (work conservation
        — recomputed in place so the same wave's later slots see it)."""
        tier = self._tier_of(t)
        if tier is None or tier not in self._wdrr_credit:
            return
        self._wdrr_credit[tier] -= 1.0
        if self._wdrr_credit[tier] < 1.0 and tier in elig:
            elig.discard(tier)
            if not elig:
                counts = self._queued_by_class()
                queued = {n for n, c in counts.items() if c}
                self._wdrr_replenish(queued)
                elig.update(n for n in queued
                            if self._wdrr_credit[n] >= 1.0)
                if not elig:
                    elig.update(queued)

    # -- latency ledger -------------------------------------------------------
    def _note_first_token(self, req: Request, now: float) -> None:
        """Stamp the request's TTFT at its prefill token.  Inherently
        stall-aware: prefill runs at *actual* admission, after any WDRR
        gating, queueing, and billed steal/rebalance stalls."""
        if req.first_token_step is None:
            req.first_token_step = int(now)
            req.last_token_step = int(now)
            self._ttft.setdefault(req.sla or "unclassed", []).append(
                int(now) - req.submit_step)

    def _note_token(self, req: Request, now: float) -> None:
        """Record one decode token's inter-token gap (engine steps since
        the previous token — >1 means the request sat out stalled steps).

        A request with multiple service intervals (parked, preempted, or
        asleep on a tool call, then spliced back) must NOT count the
        break as an inter-token gap — the old ledger did, so one sleeping
        session's think time double-counted as a monster token gap AND
        sat in the percentiles of a class that was never being served.
        The first token after a resume is flagged (``service_break``) and
        recorded in the wake-to-token ledger instead when the break was a
        wake (``wake_step`` set: tool response -> first token, the
        latency an agentic user actually feels), or dropped entirely for
        scheduler-imposed parks."""
        if req.service_break:
            req.service_break = False
            if req.wake_step is not None:
                self._wake_lat.setdefault(req.sla or "unclassed", []).append(
                    int(now) - req.wake_step)
                req.wake_step = None
        elif req.last_token_step is not None:
            self._gaps.setdefault(req.sla or "unclassed", []).append(
                int(now) - req.last_token_step)
        req.last_token_step = int(now)

    def latency_summary(self) -> dict:
        """Per-class arrival-time latency percentiles + goodput-under-SLA.

        TTFT and inter-token gaps are in engine steps, aggregated with the
        deterministic nearest-rank percentile; ``goodput`` counts completed
        requests whose TTFT met their contract class's SLO (see
        :func:`repro.serving.workload.goodput_under_sla`).

        TTFT is judged on the *first* admission only (``_note_first_token``
        never re-stamps a resumed request); re-woken service intervals
        report separately as ``wake_p50``/``wake_p99`` — tool response to
        first post-wake token — with ``wakes`` the sample count."""
        out: dict = {"classes": {}}
        for name in sorted(set(self._ttft) | set(self._gaps)
                           | set(self._wake_lat)):
            t = self._ttft.get(name, [])
            g = self._gaps.get(name, [])
            w = self._wake_lat.get(name, [])
            out["classes"][name] = {
                "n": len(t),
                "ttft_p50": percentile(t, 50),
                "ttft_p99": percentile(t, 99),
                "tok_p50": percentile(g, 50),
                "tok_p99": percentile(g, 99),
                "wakes": len(w),
                "wake_p50": percentile(w, 50),
                "wake_p99": percentile(w, 99),
            }
        if self.sla_classes:
            good, total = goodput_under_sla(self.completed, self.sla_classes)
        else:
            good, total = goodput_under_sla(self.completed)
        out["goodput"] = {"good": good, "total": total,
                          "frac": good / total if total else 1.0}
        return out

    # -- slot management ------------------------------------------------------
    def _admit(self, now: float) -> None:
        """Fill free slots from the runtime; batch every KV write.

        Parked requests (regenerated, possibly stolen meanwhile) are
        restored with a *splice* of their saved state — the next-touch
        re-home — instead of a re-prefill; fresh requests run prefill.
        All resulting single-slot states are written in one batched
        splice at the end.

        A scheduler call that accrued cost (a successful steal's remote
        lock/KV drag) stalls its slot: the claimed thread waits in
        ``_pending`` and enters the slot only once the admission-latency
        bill is paid — the slot never holds a half-migrated request whose
        state the whole-batch decode would advance."""
        writes: list[tuple[int, object]] = []
        # (exec group, prompt len) -> [(slot, req)]: fresh prompts grouped
        # into one wave-batched prefill call per host per length
        fresh: dict[tuple[int, int], list] = {}
        # SLA gate: one WDRR replenish per admission wave; the resulting
        # eligible-class set rides the covering-list walk as a task filter
        # and is spent/recomputed in place as the wave's slots admit
        elig = self._wdrr_gate() if self.sla_classes else None
        filt = self._wdrr_filter(elig) if elig is not None else None
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or self._stall[slot] > 0 \
                    or slot in self._dead_slots:
                continue
            t = self._pending.pop(slot, None)
            if t is None:
                full = self._headroom(self._page_of[slot]) \
                    < self.kv_bytes - 1e-9
                # HBM admission control: a slot of a page group at its
                # budget does not even run the scheduler call — the queued
                # gang *parks* where it is (another group's slot, or time,
                # will take it) instead of claiming KV it cannot splice in
                if full and self.capacity_aware:
                    if self.sched.queues.total_tasks():
                        self.stats.hbm_slot_waits += 1
                    continue
                # keep acquiring past stale husks: a finished-gang thread
                # (remaining 0 / request done) is dropped on sight and the
                # SAME slot looks again in the SAME wave — the old code
                # bailed after one husk and idled the slot a whole step
                # with live work still queued
                while True:
                    t, cost = self.runtime.acquire(slot, now,
                                                   task_filter=filt)
                    if cost:
                        self._stall[slot] += cost
                        self.stats.stall_steps += cost
                    if t is None or self._live_thread(t):
                        break
                    self.runtime.release(slot, t, True, now)   # husk: drop
                if t is None:
                    continue
                if elig is not None:
                    self._wdrr_spend(t, elig)
                if full:
                    # capacity-blind baseline: fullness is discovered only
                    # at splice time, *after* the claim (and after any
                    # steal dragged the loot here and billed its stall).
                    # The request bounces back onto the page's list — the
                    # thrash the capacity-aware survey exists to avoid.
                    self.stats.hbm_refusals += 1
                    self.runtime.release(slot, t, False, now)
                    self.sched.queues.covering(slot)[1].push(t)
                    continue
                self._charge(slot)            # reserve the KV bytes now
                if self._restore_debt:
                    # an orphan of a killed host pays its quoted restore /
                    # re-prefill bill here, at re-admission — the recovery
                    # compute lands as admission latency, like every other
                    # cost in the engine
                    req0 = getattr(t, "request", None)
                    debt = self._restore_debt.pop(req0.rid, 0.0) \
                        if req0 is not None else 0.0
                    if debt:
                        self._stall[slot] += debt
                        self.stats.stall_steps += debt
                if self._stall[slot] > 0:     # pay the migration first
                    self._pending[slot] = t
                    continue
            req: Request = t.request                      # type: ignore
            self.slot_req[slot] = req
            self.slot_thread[slot] = t
            # data policy: first/next-touch homing of the gang's KV pages
            self.runtime.touch(slot, t)
            parked = self._kv_park.pop(req.rid, None)
            if parked is not None:
                st, tok = parked
                self.tokens[slot, 0] = tok    # resume the continuation
                req.service_break = True      # next token is not a gap
                writes.append((slot, st))
            elif self.wave_prefill:
                # defer: fresh prompts of one wave batch into one prefill
                # call per (host, prompt length) — see below
                key = (self._group_of[slot], len(req.prompt))
                fresh.setdefault(key, []).append((slot, req))
            else:
                tok, st = self.backend.prefill(req.prompt)
                req.out_tokens.append(tok)
                self._note_first_token(req, now)
                self.tokens[slot, 0] = tok
                self.stats.prefills += 1
                writes.append((slot, st))
        # wave-batched prefill: the per-request loop this replaces ran one
        # model call per fresh prompt; the splice below was already batched
        for (_, _), batch in fresh.items():
            results = self.backend.prefill_wave(
                [req.prompt for _, req in batch])
            self.stats.prefill_waves += 1
            for (slot, req), (tok, st) in zip(batch, results):
                req.out_tokens.append(tok)
                self._note_first_token(req, now)
                self.tokens[slot, 0] = tok
                self.stats.prefills += 1
                writes.append((slot, st))
        if writes:
            # one batched splice per host batch (execution group): each
            # group's KV shard is written in a single traversal
            by_group: dict[int, list[tuple[int, object]]] = {}
            for slot, st in writes:
                g = self._group_of[slot]
                lo = self._exec_groups[g][0]
                by_group.setdefault(g, []).append((slot - lo, st))
            for g, pairs in by_group.items():
                self._states[g] = self.backend.splice(self._states[g], pairs)
                self.stats.kv_splices += 1
            self.stats.kv_spliced_slots += len(writes)

    def _evict(self, slot: int, now: float) -> None:
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            req.finish_step = int(now)
            self.completed.append(req)
        self.slot_req[slot] = None
        t = self.slot_thread.pop(slot, None)
        if t is not None:
            # the prefill token counts toward max_new_tokens but never
            # decremented `remaining`; zero it so a later gang regeneration
            # cannot resurrect the finished thread
            t.remaining = 0.0
            self.runtime.release(slot, t, True, now)
        self._refund(slot)                    # its KV bytes leave the budget
        rel = getattr(self.backend, "release", None)
        if rel is not None:
            # paged backends reclaim the slot's KV pages on eviction (a
            # metadata edit); dense backends have nothing to free
            g = self._group_of[slot]
            self._states[g] = rel(self._states[g],
                                  slot - self._exec_groups[g][0])
        self.tokens[slot, 0] = 0              # freed slot: no stale decode

    # -- multilevel-feedback demotion + SLA preemption ------------------------
    def _maybe_demote(self, req: Request, t: Thread) -> None:
        """Multilevel-feedback rule: a request that has decoded past its
        scheduling tier's ``demote_after`` sinks to ``demote_to`` — it
        stops competing (WDRR, priority, preemption shielding) as the
        short job it no longer is.  The CONTRACT class (``req.sla``) never
        changes: the ledger still judges it by what was promised."""
        if not self.sla_classes:
            return
        cls = self.sla_classes.get(req.tier) if req.tier else None
        if (cls is None or cls.demote_after is None
                or len(req.out_tokens) < cls.demote_after
                or cls.demote_to not in self.sla_classes):
            return
        req.tier = cls.demote_to
        t.prio = self.sla_classes[req.tier].prio
        self.stats.demotions += 1

    def _park_request(self, slot: int, now: float) -> None:
        """Single-request preemption: extract the slot's KV state and last
        token into ``_kv_park`` (the later re-admission resumes the
        continuation via the batched splice — no re-prefill), free the
        slot, and re-queue the thread on its page group's list so the
        resume finds its KV-affine slots first.  The gang-sized variant is
        :meth:`regenerate_gang` (parks every member, re-queues the closed
        bubble)."""
        req = self.slot_req[slot]
        t = self.slot_thread.pop(slot)
        self.slot_req[slot] = None
        g = self._group_of[slot]
        self._kv_park[req.rid] = (
            self.backend.extract(self._states[g],
                                 slot - self._exec_groups[g][0]),
            int(self.tokens[slot, 0]))
        self.stats.kv_parks += 1
        self.tokens[slot, 0] = 0
        self._refund(slot)    # parked KV lives host-side, off the budget
        self.runtime.release(slot, t, False, now)
        self.sched.queues.covering(slot)[1].push(t)

    def _maybe_preempt(self, now: float) -> None:
        """Under pressure, park a preemptible tier's work to admit an
        urgent class: fires when a ``preempts`` class has live queued work,
        no slot is free to take it, and the cooldown has elapsed.  One
        victim per firing — the preemptible gang (or lone request) with
        the most remaining decode, so the freed capacity is reclaimed for
        the longest.  Victims are parked via the KV park/splice path and
        resume later exactly where they left off."""
        if not self.preempt:
            return
        if self.steps - self._last_preempt <= self.preempt_cooldown:
            return
        urgent = {n for n, c in self.sla_classes.items() if c.preempts}
        if not urgent:
            return
        counts = self._queued_by_class()
        if not any(counts.get(n, 0) for n in urgent):
            return
        if any(self.slot_req[s] is None and self._stall[s] <= 0
               and s not in self._pending and s not in self._dead_slots
               for s in range(self.n_slots)):
            return          # a slot opens this wave anyway: no parking
        # victim survey: preemptible-tier residents, gangs counted whole
        best = None                  # (remaining, "gang"/"solo", payload)
        gang_slots: dict[str, list[int]] = {}
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is None or req.done or s in self._thinking:
                continue          # thinking slots hold no spliceable state
            cls = self.sla_classes.get(req.tier) if req.tier else None
            if cls is None or not cls.preemptible:
                continue
            if req.gang is not None:
                gang_slots.setdefault(req.gang, []).append(s)
            else:
                rem = req.max_new_tokens - len(req.out_tokens)
                if rem > 0 and (best is None or rem > best[0]):
                    best = (rem, "solo", s)
        for gname, slots in gang_slots.items():
            rem = sum(self.slot_req[s].max_new_tokens
                      - len(self.slot_req[s].out_tokens) for s in slots)
            if rem > 0 and (best is None or rem > best[0]):
                best = (rem, "gang", gname)
        if best is None:
            return
        if best[1] == "gang":
            self.stats.preempt_parks += self.regenerate_gang(best[2])
        else:
            self._park_request(best[2], now)
            self.stats.preempt_parks += 1
        self.stats.preemptions += 1
        self._last_preempt = self.steps

    # -- queue-depth rebalance trigger ----------------------------------------
    def _page_depths(self) -> list[int]:
        """Runnable decode threads pinned under each page group's lists
        (work on the global list is reachable by every slot and is not
        skew)."""
        depths = []
        for comp in self.topo.components("page"):
            n = 0
            for sub in self.sched._bfs(comp):
                for task in self.sched.queues.queue_of(sub).tasks:
                    if isinstance(task, Bubble):
                        n += sum(1 for th in task.threads()
                                 if th.remaining > 0)
                    elif task.remaining > 0:
                        n += 1
            depths.append(n)
        return depths

    _NO_SCOPE = object()       # sentinel: no re-spread is worth buying

    def _rebalance_candidates(self, depths: list[int]) -> list:
        """Candidate re-spread scopes, most local first: every host whose
        *own* page depths are skewed (a host-local re-spread can fix those
        without quoting a single DCN crossing), then the whole machine
        (``None``).  The flat mode — and any single-host fleet — only ever
        has the machine-wide candidate."""
        cands = []
        if self.dcn_rebalance and self._host_idx is not None:
            # grouped by the host COMPONENT itself, not by round-tripping
            # ``component.index`` through ``topo.components("host")`` — the
            # old lookup silently assumed ``.index`` equals list position,
            # which nothing in Topology guarantees to a consumer; keying by
            # identity scopes the re-spread to the exact component whose
            # pages are skewed on any pod/host layout, ragged or not
            by_host: dict[int, tuple] = {}   # id(host) -> (host, depths)
            for p, d in enumerate(depths):
                h = self._page_host[p]
                by_host.setdefault(id(h), (h, []))[1].append(d)
            for h, ds in by_host.values():
                if len(ds) >= 2 and max(ds) - min(ds) >= self.depth_skew:
                    cands.append(h)
        cands.append(None)
        return cands

    def _choose_rebalance_scope(self, depths: list[int], paid: float):
        """Pick the cheapest re-spread worth buying, or ``_NO_SCOPE``.

        With ``dcn_rebalance`` each candidate is quoted through
        :meth:`BubbleScheduler.estimate_rebalance` — every prospective
        move priced by the boundary it crosses via the cost model's
        ``level_table`` — and the cheapest worthwhile quote wins, ties to
        the most local.  That is the whole point of the mode: when remote
        backlog makes the machine-wide quote dear (per-move DCN tolls), a
        host-local page shuffle that fixes the *local* skew is bought
        instead.  Flat mode keeps the historical single machine-wide test
        (flat per-move estimate), bit for bit."""
        if not self.dcn_rebalance:
            # flat mode: the historical single machine-wide test, bit for
            # bit (flat per-move estimate via queued_movable)
            if self.runtime.rebalance_worth_it(
                    paid, min_backlog=self.min_backlog, level="page"):
                return None
            return self._NO_SCOPE
        if paid <= self.sched.cost_model.rebalance_base:
            return self._NO_SCOPE           # cannot cover even the base
        best, best_cost = self._NO_SCOPE, None
        for scope in self._rebalance_candidates(depths):
            # one quote per candidate: worth-it test AND ranking read the
            # same estimate (quoting replays the whole LPT deal — doing
            # it twice per candidate would double the trigger's hot-path
            # work for nothing)
            movable, est = self.sched.estimate_rebalance("page", scope)
            if movable < self.min_backlog or paid <= est:
                continue
            if best_cost is None or est < best_cost:
                best, best_cost = scope, est
        return best

    def _maybe_rebalance(self, now: float) -> None:
        """Decode-gang queue depths feed the same cost-benefit test the
        adaptive simulator policy uses: when one page group's backlog
        outruns another's by ``depth_skew`` and the steal cost recently
        paid exceeds one bulk re-spread's bill, re-spread across the page
        groups instead of letting slots drain the skew one costed steal at
        a time.  Under ``dcn_rebalance`` the re-spread itself is chosen by
        quote: host-local when the machine-wide deal would pay DCN tolls
        the local fix avoids (:meth:`_choose_rebalance_scope`)."""
        if self.mode != "runtime":
            return
        s = self.sched.stats
        self._paid.append(s.steal_cost - self._cost_mark)
        self._cost_mark = s.steal_cost
        if len(self._paid) > self.window:
            self._paid.popleft()
        self._steps_since_rebalance += 1
        if self._steps_since_rebalance < self.cooldown:
            return
        depths = self._page_depths()
        if len(depths) < 2 or max(depths) - min(depths) < self.depth_skew:
            return
        scope = self._choose_rebalance_scope(depths, sum(self._paid))
        if scope is self._NO_SCOPE:
            return
        # bill the re-spread to (a slot of) the emptiest page group in the
        # chosen scope — the one whose starvation triggered it.  The
        # scheduler accrues the cost for its *next* consume_cost() caller,
        # which outside an acquire would be an arbitrary slot; drain it
        # here and stall the triggering slot explicitly instead.
        pages = [p for p in range(len(depths))
                 if scope is None or self._page_host[p] is scope]
        page = min(pages, key=depths.__getitem__)
        slot = next(iter(self.topo.components("page")[page].leaves())).cpu
        self.runtime.rebalance(slot, now, level="page", scope=scope)
        cost = self.policy.consume_cost()
        if cost:
            self._stall[slot] += cost
            self.stats.stall_steps += cost
        # the DCN side of the bill lands where the KV lands: every slot of
        # a page group that received boundary-crossing loot waits out the
        # transfer (the group's level-table toll) before its next
        # admission — a machine-wide re-spread that scatters work across
        # hosts freezes admissions fleet-wide, which is exactly why the
        # priced trigger above prefers the host-local fix.  Single-host
        # deals cross no tabled boundary: ingest is empty, nothing stalls.
        for comp_name, extra in self.sched.stats.last_rebalance_ingest.items():
            for leaf in self.topo.component(comp_name).leaves():
                self._stall[leaf.cpu] += extra
                self.stats.stall_steps += extra
        self.stats.rebalances += 1
        if scope is not None:
            self.stats.local_rebalances += 1
        self._paid.clear()
        self._cost_mark = self.sched.stats.steal_cost
        self._steps_since_rebalance = 0

    # -- HBM-aware gang splitting ----------------------------------------------
    def _split_wait_quote(self, page_comp, deficit: float) -> float:
        """Engine steps until page group ``page_comp`` frees ``deficit``
        KV bytes by residents finishing on their own — the park-and-wait
        alternative a gang split is quoted against.  The k-th soonest
        resident completion covers a k-reservation deficit; a group
        without enough residents to ever free it quotes infinite.  (Takes
        the component itself: after an elastic ``kill_host`` a component's
        ``.index`` no longer equals its ``components("page")`` position,
        so positional round-trips would quote the wrong group.)"""
        k = int(np.ceil(deficit / self.kv_bytes - 1e-9))
        if k <= 0:
            return 0.0
        rems = sorted(
            req.max_new_tokens - len(req.out_tokens)
            for leaf in page_comp.leaves()
            if (req := self.slot_req[leaf.cpu]) is not None and not req.done)
        if len(rems) < k:
            return float("inf")
        return float(rems[k - 1])

    def _maybe_split_gang(self, now: float) -> None:
        """When the HBM ledger refuses a whole-gang admission, quote
        splitting the gang across sibling page groups of its host against
        parking until the home group drains, and buy the cheaper.

        The stuck state this resolves: a closed gang bubble homed on a
        page-level list whose group cannot hold every live member.  The
        group's own slots skip their scheduler calls (capacity-aware
        admission), and every other group's steal survey refuses the
        bubble whole (``_can_accept`` needs the full gang's KV), so
        without this pass the gang waits for its home group to drain —
        correct, but not always cheapest.  The split is the paper's
        bubble-burst semantics applied one level early: the bubble is
        expanded onto its host's list (scheduling area widened one
        level), members that fit stay on the home group, and the overflow
        is re-homed to the siblings with headroom.  The quote prices each
        re-homed member's ``page`` crossing at ``cost_model`` (belief)
        prices — byte-priced under a bandwidth table, since what moves is
        KV — and the bill lands at ``bill_model`` (machine) prices as
        admission stalls, transfer tolls on the receiving groups."""
        if not self.gang_split or self.mode != "runtime" \
                or self.hbm_budget is None:
            return
        for page_comp in self.topo.components("page"):
            q = self.sched.queues.queue_of(page_comp)
            for b in list(q.tasks):
                if not isinstance(b, Bubble) or b.burst or b.done():
                    continue
                live = [th for th in b.threads() if self._live_thread(th)]
                if not live:
                    continue
                need = self.kv_bytes * len(live)
                if need <= self._headroom(page_comp.index) + 1e-9:
                    continue          # fits whole: normal burst admission
                self._split_gang(b, q, page_comp, live, now)

    def _split_gang(self, b: Bubble, q, page_comp, live: list, now: float
                    ) -> None:
        """Quote and (when cheaper than waiting) commit one gang split."""
        kv = self.kv_bytes
        host = self.topo.ancestor_at(page_comp, "host") or self.topo.root
        sibs = [c for c in self.topo.components("page")
                if c is not page_comp and host in c.path()]
        room = {id(c): self._headroom(c.index) for c in sibs}
        fit_home = int((self._headroom(page_comp.index) + 1e-9) // kv)
        plan: list[tuple] = []        # (member, destination page group)
        for th in live[fit_home:]:
            dest = max(sibs, key=lambda c: room[id(c)], default=None)
            if dest is None or room[id(dest)] < kv - 1e-9:
                return       # siblings cannot absorb the overflow: park
            room[id(dest)] -= kv
            plan.append((th, dest))
        cm = self.sched.cost_model
        split_quote = sum(
            cm.rebalance_move_cost(
                self.topo.crossing_between(page_comp, dest), kv)
            for _, dest in plan)
        deficit = kv * len(live) - self._headroom(page_comp.index)
        if split_quote >= self._split_wait_quote(page_comp, deficit):
            return                    # waiting is quoted cheaper: park
        # buy the split: expand the bubble one level up (its regeneration
        # home is now the host's list) with explicit member placement
        q.remove(b)
        b.burst = True
        b.released_at = now
        b.home_list = self.sched.queues.queue_of(host)
        for th in live[:fit_home]:
            q.push(th)
        for th, dest in plan:
            self.sched.queues.queue_of(dest).push(th)
        # the bill, at machine (bill_model) prices: the flat descriptor
        # part stalls the home group's first slot (whose refused admission
        # triggered the quote); each receiving group's slots wait out the
        # byte-priced transfer toll of the KV dealt into it — the same
        # split billing discipline as `_maybe_rebalance`'s ingest side
        bm = self.sched.bill_model
        flat = bm.rebalance_per_move * len(plan)
        if flat > 0:
            home_slot = next(iter(page_comp.leaves())).cpu
            self._stall[home_slot] += flat
            self.stats.stall_steps += flat
        tolls: dict[int, tuple] = {}      # id(dest) -> (dest, toll)
        for th, dest in plan:
            move = bm.rebalance_move_cost(
                self.topo.crossing_between(page_comp, dest), kv)
            extra = move - bm.rebalance_per_move
            if extra > 0:
                prev = tolls.get(id(dest), (dest, 0.0))[1]
                tolls[id(dest)] = (dest, prev + extra)
        for dest, toll in tolls.values():
            for leaf in dest.leaves():
                self._stall[leaf.cpu] += toll
                self.stats.stall_steps += toll
        self.stats.gang_splits += 1
        self.stats.gang_split_members += len(plan)

    # -- the decode loop -------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: consider a rebalance, admit, decode one
        token for every occupied unstalled slot, retire finished requests.
        Returns #slots decoded.

        Decode is driven **per host batch**: each execution group with any
        occupied slot gets its own ``decode_step`` over its own KV shard
        (one jit per host batch on the jax backend); a host whose batch is
        empty this step skips the call entirely.  Slots are independent in
        every backend, so the union of per-host calls decodes exactly what
        one global call would — sharding execution models per-shard
        latency without touching the streams."""
        now = float(self.steps)
        self.steps += 1
        if self.kv_store is not None:
            self._maybe_snapshot_kv(int(now))
        if self._sleeping or self._thinking:
            # tool responses land before admission, so a woken session can
            # re-enter a slot (and decode) in the very step it wakes
            self._process_wakes(now)
        self._maybe_rebalance(now)
        self._maybe_preempt(now)
        self._admit(now)
        # after admission, so the ledger reflects what actually occupies
        # each group — a pre-admission check would quote deficits against
        # reservations the same wave's claims are about to take
        self._maybe_split_gang(now)
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None
                  and s not in self._thinking]
        if self._thinking:
            # the hold-the-slot cost, in its own currency: occupied slots
            # decoding nothing while their session waits on a tool
            self.stats.hold_slot_steps += len(self._thinking)
        for s in range(self.n_slots):
            if self._stall[s] > 0:
                self._stall[s] = max(0.0, self._stall[s] - 1.0)
        if not active:
            return 0
        for g, (lo, hi) in enumerate(self._exec_groups):
            active_g = [s for s in active if lo <= s < hi]
            if not active_g:
                continue                     # idle host: no decode launched
            # straggler model: a host earns ``speed`` credit per engine
            # step its batch is occupied and decodes only on a whole
            # credit — a 0.5x host's decode_step spans two engine steps.
            # Nominal speed earns exactly 1.0 per step: bit-identical.
            self._host_credit[g] += self._group_speed[g]
            if self._host_credit[g] < 1.0 - 1e-9:
                self.stats.host_skipped_steps[g] += 1
                continue                     # slow host: decode not done yet
            self._host_credit[g] -= 1.0
            next_tok, self._states[g] = self.backend.decode(
                self.tokens[lo:hi], self._states[g])
            self.stats.host_decode_steps[g] += 1
            self.stats.host_active_slots[g] += len(active_g)
            for s in active_g:
                self.tokens[s, 0] = next_tok[s - lo]
                req = self.slot_req[s]
                req.out_tokens.append(int(next_tok[s - lo]))
                self._note_token(req, now)
                t = self.slot_thread[s]
                t.remaining -= 1.0
                if len(req.out_tokens) >= req.max_new_tokens:
                    self._evict(s, now)
                elif (req.next_call < len(req.tool_calls)
                      and len(req.out_tokens)
                      >= req.tool_calls[req.next_call][0]):
                    self._tool_call(s, now)
                else:
                    self._maybe_demote(req, t)
        return len(active)

    def _drained(self) -> bool:
        return (not any(self.slot_req) and not self._pending
                and not self._sleeping and not self._thinking
                and self.sched.queues.total_tasks() == 0
                and not any(st > 0 for st in self._stall))

    def run(self, max_steps: int = 1000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if self._drained():
                break
        return self.completed

    # -- regeneration (backpressure / straggling client) ------------------------
    def regenerate_gang(self, gang: str) -> int:
        """Pull a gang's requests out of the slots — parking each slot's KV
        state and last token so the later re-admission resumes the
        continuation via the batched splice — and re-queue the closed
        bubble (affinity preserved).

        The old engine left the freed slots' tokens and the popped threads'
        running state behind: a re-queued gang decoded from stale tokens
        and could never be woken again once finished."""
        b = self._gangs.get(f"gang:{gang}")
        if b is None:
            return 0
        now = float(self.steps)
        # Members freed below go back onto a list *before* the bubble is
        # regenerated.  If the gang bubble is still a burst husk the
        # regeneration collects them (queued children are folded back in);
        # but a closed bubble that a rebalance has *expanded* is itself on
        # no queue and regenerate() is a no-op for it — releasing a member
        # into thin air would lose the request forever (found by the HBM
        # admit/park/steal property test).
        fold = b.home_list if b.home_list is not None \
            else self.sched.queues.global_queue()
        # a member claimed into _pending (waiting out its steal stall) goes
        # back into the bubble: the regenerated gang re-pushes it at its
        # next burst, and leaving it pending too would double-schedule it
        for s, t in list(self._pending.items()):
            if t.parent is b:
                del self._pending[s]
                self._refund(s)               # reservation never spliced in
                self.runtime.release(s, t, False, now)
                fold.push(t)
        n = 0
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is not None and req.gang == gang and not req.done:
                t = self.slot_thread.pop(s)
                self.slot_req[s] = None
                if s in self._thinking:
                    # a hold-mode member mid-think: its KV is already
                    # extracted into the thinking entry — converting it to
                    # a ledger sleep (slot freed, wake deadline kept) is
                    # the only move that neither double-extracts nor
                    # collapses the pending tool response
                    e = self._thinking.pop(s)
                    self.tokens[s, 0] = 0
                    self._refund(s)
                    self.runtime.release(s, t, False, now)
                    if t.parent is not None:
                        t.parent.children.remove(t)
                        t.parent = None
                    self._sleeping.add(e)
                    self.stats.sleeps += 1
                    n += 1
                    continue
                g = self._group_of[s]
                self._kv_park[req.rid] = (
                    self.backend.extract(self._states[g],
                                         s - self._exec_groups[g][0]),
                    int(self.tokens[s, 0]))
                self.stats.kv_parks += 1
                self.tokens[s, 0] = 0
                self._refund(s)   # parked KV lives host-side, off the budget
                self.runtime.release(s, t, False, now)
                fold.push(t)
                n += 1
        self.sched.regenerate(b, running={})
        return n

    # -- agentic sessions: tool-call sleep / wake ------------------------------
    def _tool_call(self, slot: int, now: float) -> None:
        """The resident request just hit its next tool-call marker: block
        it on the external event — sleep-and-release or hold-the-slot,
        per the engine's ``agentic_sleep`` knob."""
        req = self.slot_req[slot]
        _, think = req.tool_calls[req.next_call]
        req.next_call += 1
        wake_at = None if think is None else int(now) + int(think)
        if self.agentic_sleep:
            self._sleep_slot(slot, wake_at, now)
        else:
            self._hold_slot(slot, wake_at, now)

    def _sleep_slot(self, slot: int, wake_at: Optional[int], now: float
                    ) -> None:
        """Park the slot's KV and free it: the session's thread leaves
        every run queue (held in the SleepingLedger — a sleeping session
        is not schedulable work) and, unless ``sleep_retain_hbm``, its
        HBM reservation is refunded.  The freed slot admits someone else
        in the next wave: under load, this is where the capacity headroom
        comes from."""
        req = self.slot_req[slot]
        t = self.slot_thread.pop(slot)
        self.slot_req[slot] = None
        g = self._group_of[slot]
        handle = self.backend.extract(self._states[g],
                                      slot - self._exec_groups[g][0])
        entry = SleepEntry(req.rid, t, handle, int(self.tokens[slot, 0]),
                           self.topo.cpus[slot].path()[self._page_idx],
                           int(now), wake_at)
        self.tokens[slot, 0] = 0
        if self.sleep_retain_hbm and self._slot_charged[slot]:
            # keep the bytes reserved in the home group for the wake, but
            # detach them from the slot (someone else's claim will charge
            # it normally); released when the entry leaves the ledger
            entry.retained = self._page_of[slot]
            self._slot_charged[slot] = False
        else:
            self._refund(slot)
        self.runtime.release(slot, t, False, now)
        # detach a gang member from its bubble: a later burst of the
        # (regenerated) gang would otherwise re-push the sleeping thread
        # onto a run queue and double-schedule it on wake
        if t.parent is not None:
            t.parent.children.remove(t)
            t.parent = None
        self._sleeping.add(entry)
        self.stats.sleeps += 1
        self.stats.kv_parks += 1

    def _hold_slot(self, slot: int, wake_at: Optional[int], now: float
                   ) -> None:
        """The baseline: keep the slot (and its HBM reservation) through
        the think gap.  The KV is still extracted — the whole-host-batch
        decode advances every resident state, so a thinking slot's state
        must sit out host-side and be spliced back on wake or the
        continuation would be corrupted — but the slot admits nobody."""
        req = self.slot_req[slot]
        g = self._group_of[slot]
        handle = self.backend.extract(self._states[g],
                                      slot - self._exec_groups[g][0])
        self._thinking[slot] = SleepEntry(
            req.rid, self.slot_thread[slot], handle,
            int(self.tokens[slot, 0]),
            self.topo.cpus[slot].path()[self._page_idx], int(now), wake_at)
        self.tokens[slot, 0] = 0
        self.stats.holds += 1

    def _process_wakes(self, now: float) -> None:
        """Deliver scheduled tool responses: splice thinking slots back in
        place (hold mode), wake due ledger entries onto run queues (sleep
        mode), then drop the KV of sessions sleeping past the TTL."""
        for slot in sorted(self._thinking):
            e = self._thinking[slot]
            if e.wake_at is not None and e.wake_at <= now:
                self._wake_hold(slot, now)
        for e in self._sleeping.due(now):
            self._wake_entry(e, now)
        if self.session_ttl is not None:
            for e in self._sleeping.stale(now, self.session_ttl):
                self._evict_stale(e)

    def _wake_hold(self, slot: int, now: float) -> None:
        """Hold-mode wake: splice the held state back into the slot it
        never gave up."""
        e = self._thinking.pop(slot)
        req = self.slot_req[slot]
        g = self._group_of[slot]
        self._states[g] = self.backend.splice(
            self._states[g], [(slot - self._exec_groups[g][0], e.state)])
        self.stats.kv_splices += 1
        self.stats.kv_spliced_slots += 1
        self.tokens[slot, 0] = e.token
        req.wake_step = int(now)
        req.service_break = True
        self.stats.wakes += 1

    def _queue_wait_quote(self, page_comp, depth: int) -> float:
        """Expected wait (engine steps) before a page group can serve one
        more request: its queued backlog spread over its slots, plus —
        when the group is at its HBM budget — the time until residents
        free one reservation on their own (``_split_wait_quote``)."""
        w = depth / max(sum(1 for _ in page_comp.leaves()), 1)
        if self.hbm_budget is not None:
            need = self.kv_bytes - self._headroom(page_comp.index)
            if need > 1e-9:
                w += self._split_wait_quote(page_comp, need)
        return w

    def _wake_dest(self, entry: SleepEntry):
        """The wake-affinity quote: where should this session resume?

        Home is free (the KV handle splices back as a metadata edit on
        the paged backend); any other page group pays the believed
        transfer toll (``cost_model.rebalance_move_cost`` over the
        boundary crossed, byte-priced under a bandwidth table) on top of
        its queue/HBM wait.  The cheapest total wins, ties to home — so
        an idle fleet always restores affinity, and only genuine pressure
        at home (backlog, a full budget) buys the away move.  A home
        group lost to ``kill_host`` quotes infinite and the live groups
        compete on their own merits."""
        pages = self.topo.components("page")
        if not self.wake_quote:
            return entry.home_page if any(
                p is entry.home_page for p in pages) else pages[0]
        depths = self._page_depths()
        cm = self.sched.cost_model
        ranked = sorted(zip(pages, depths),
                        key=lambda pd: pd[0] is not entry.home_page)
        best, best_q = None, None
        for comp, depth in ranked:          # home first: wins ties
            toll = 0.0 if comp is entry.home_page else \
                cm.rebalance_move_cost(
                    self.topo.crossing_between(entry.home_page, comp),
                    self.kv_bytes)
            q = self._queue_wait_quote(comp, depth) + toll
            if best_q is None or q < best_q - 1e-9:
                best, best_q = comp, q
        return best

    def _wake_entry(self, e: SleepEntry, now: float) -> None:
        """Sleep-mode wake: the tool response landed.  Rebuild the
        continuation if the KV was stale-evicted (full-history re-prefill,
        billed at ``reprefill_unit`` per token like a kill_host orphan),
        park it for the admission splice, and push the thread where the
        wake-affinity quote says — an away move is billed at bill-model
        prices as an admission stall and flags the thread ``stolen`` so
        next-touch re-homes the session's KV data object."""
        req = self._reqs[e.rid]
        t = e.thread
        self._sleeping.pop(e.rid)
        if e.retained is not None:
            self.hbm_used[e.retained] -= self.kv_bytes
            e.retained = None
        if e.state is None:
            m = len(req.out_tokens)
            hist = req.prompt if m == 1 else np.concatenate(
                [req.prompt, np.asarray(req.out_tokens[:-1], np.int32)])
            _, st = self.backend.prefill(hist)
            tok = int(req.out_tokens[-1])
            debt = (len(req.prompt) + m - 1) * self.reprefill_unit
            if debt:
                self._restore_debt[req.rid] = \
                    self._restore_debt.get(req.rid, 0.0) + debt
            self.stats.wake_reprefills += 1
        else:
            st, tok = e.state, e.token
        self._kv_park[req.rid] = (st, tok)
        dest = self._wake_dest(e)
        if dest is e.home_page:
            self.stats.wake_home += 1
        else:
            self.stats.wake_away += 1
            bill = self.sched.bill_model.rebalance_move_cost(
                self.topo.crossing_between(e.home_page, dest),
                self.kv_bytes)
            if bill:
                self._restore_debt[req.rid] = \
                    self._restore_debt.get(req.rid, 0.0) + bill
            t.stolen = True          # next touch re-homes the KV data id
        self.sched.queues.queue_of(dest).push(t)
        req.wake_step = int(now)
        self.stats.wakes += 1

    def _evict_stale(self, e: SleepEntry) -> None:
        """Drop a sleeping session's parked KV (its pages go back to the
        pool on a paged backend); the entry survives — a later wake
        re-prefills the continuation from the token history."""
        drop = getattr(self.backend, "drop", None)
        if drop is not None:
            drop(e.state)
        e.state = None
        if e.retained is not None:
            self.hbm_used[e.retained] -= self.kv_bytes
            e.retained = None
        self.stats.stale_evictions += 1

    def wake(self, rid: int) -> bool:
        """Deliver a tool response from the client side: wake session
        ``rid`` now.  Markers submitted with ``think_steps=None`` wait
        for exactly this call (``run()`` alone will not drain them);
        scheduled markers wake themselves and need it only to wake
        *early*.  Returns False when ``rid`` is not asleep."""
        now = float(self.steps)
        e = self._sleeping.get(rid)
        if e is not None:
            self._wake_entry(e, now)
            return True
        for slot, e in list(self._thinking.items()):
            if e.rid == rid:
                self._wake_hold(slot, now)
                return True
        return False

    # -- elastic fleet: live host loss / join ---------------------------------
    def _maybe_snapshot_kv(self, step: int) -> None:
        """On the store's cadence, snapshot every resident continuation:
        (backend state via the non-mutating ``peek``, last emitted token,
        tokens emitted so far) per live request.  Parked continuations are
        already host-side and need no snapshot."""
        if not self.kv_store.due(step):
            return
        entries: dict[int, tuple] = {}
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is None or req.done or not req.out_tokens:
                continue
            g = self._group_of[s]
            st = self.backend.peek(self._states[g],
                                   s - self._exec_groups[g][0])
            entries[req.rid] = (st, int(self.tokens[s, 0]),
                                len(req.out_tokens))
        self.kv_store.maybe_snapshot(step, entries)

    def _buy_redeal(self, slot: int, now: float) -> None:
        """Commit one machine-wide re-spread and land its bill exactly the
        way :meth:`_maybe_rebalance` does: the flat trigger-side cost
        stalls the triggering slot, the level-table ingest tolls stall the
        receiving groups' slots, and the steal-spend window resets."""
        self.runtime.rebalance(slot, now, level="page")
        cost = self.policy.consume_cost()
        if cost:
            self._stall[slot] += cost
            self.stats.stall_steps += cost
        for comp_name, extra in self.sched.stats.last_rebalance_ingest.items():
            for leaf in self.topo.component(comp_name).leaves():
                self._stall[leaf.cpu] += extra
                self.stats.stall_steps += extra
        self.stats.rebalances += 1
        self._paid.clear()
        self._cost_mark = self.sched.stats.steal_cost
        self._steps_since_rebalance = 0

    def kill_host(self, name: str, *, restart: bool = False) -> dict:
        """Remove host ``name`` mid-flight — the elastic failure path.

        The dead host's slots leave the hierarchy (fresh ``KeyError`` for
        stale handles, cpu ids never renumber), its residents' KV
        reservations vanish from the HBM ledger (the pages died with the
        host — no extract), queued work homed anywhere in its subtree
        folds one level up onto the surviving parent list (the paper's
        §3.3.3 regeneration move, affinity kept as wide as the loss
        allows), and every orphaned request is re-parked as a
        continuation: restored from the newest ``kv_store`` snapshot (a
        ``kv_restore_level`` boundary toll on its KV bytes plus a
        teacher-forced replay of the tokens emitted since, at
        ``reprefill_unit`` steps/token) or re-prefilled from its whole
        history — whichever the cost model quotes cheaper.  The quote is
        billed as an admission stall when the orphan re-enters a
        surviving slot, and the exact rebalance quote then re-deals the
        survivor fleet.  Parked continuations (``_kv_park``) survive: they
        live host-side, not in the dead host's HBM.

        ``restart=True`` models the drain-and-restart operator instead —
        the baseline ``serve/host_loss_goodput`` gates against: the whole
        job restarts on the survivor mesh, so every in-flight request
        *fleet-wide* is torn down and re-prefilled from scratch, snapshots
        unused.

        Returns a summary dict (orphan count, restore/re-prefill split,
        re-deal quote).  Streams are unaffected: a restored or
        re-prefilled orphan continues token-for-token where it left off
        (teacher forcing — property-tested).
        """
        assert self.mode == "runtime", "kill_host needs the runtime engine"
        assert self._host_idx is not None, \
            "single-host topology has no host level to kill"
        assert self.per_host_decode, "kill_host needs per-host execution"
        host = self.topo.component(name)
        assert host.level.name == "host", f"{name!r} is not a host"
        assert any(h is not host for h in self.topo.components("host")), \
            "cannot kill the last host"
        now = float(self.steps)
        dead = {leaf.cpu for leaf in host.leaves()}
        fold = self.sched.queues.queue_of(host.parent)
        gq = self.sched.queues.global_queue()
        snaps = {} if (restart or self.kv_store is None) \
            else self.kv_store.restore()

        # 1. claims pending on doomed slots dissolve: the thread was never
        #    spliced in, so it simply returns to a surviving list (its
        #    parked KV, if any, is host-side and intact)
        requeued = 0
        for s in list(self._pending):
            if restart or s in dead:
                t = self._pending.pop(s)
                self._refund(s)
                self.runtime.release(s, t, False, now)
                (gq if restart else fold).push(t)
                requeued += 1

        # 2. residents of doomed slots are orphans: pop the thread, free
        #    the slot — their KV is gone, restoration is decided below.
        #    A thinking (hold-mode) resident's held handle counts as died
        #    with its host too: drop it (freeing pool pages if the shard
        #    survives a restart teardown) and let the orphan path rebuild
        #    the continuation from history like any other resident.
        drop = getattr(self.backend, "drop", None)
        for s in list(self._thinking):
            if restart or s in dead:
                e = self._thinking.pop(s)
                if drop is not None and s not in dead:
                    drop(e.state)
        orphans: list[tuple] = []
        doomed = range(self.n_slots) if restart else sorted(dead)
        for s in doomed:
            if s in self._dead_slots:
                continue
            self._stall[s] = 0.0
            req = self.slot_req[s]
            if req is None or req.done:
                continue
            t = self.slot_thread.pop(s)
            self.slot_req[s] = None
            self.tokens[s, 0] = 0
            self._refund(s)
            self.runtime.release(s, t, False, now)
            orphans.append((req, t))

        # 3. queued tasks homed in the dead subtree move one level up;
        #    bubbles whose regeneration home died re-home the same way
        moved_q = 0
        dead_comps, stack = [], [host]
        while stack:
            c = stack.pop()
            dead_comps.append(c)
            stack.extend(c.children)
        dead_ids = {id(c) for c in dead_comps}
        for c in dead_comps:
            q = self.sched.queues.queue_of(c)
            for task in list(q.tasks):
                q.remove(task)
                fold.push(task)
                moved_q += 1
        for b in self._gangs.values():
            if b.home_list is not None and id(b.home_list.comp) in dead_ids:
                b.home_list = fold

        # 4. topology surgery + derived-cache rebuild
        self.topo.remove_component(name)
        self.sched.queues.sync()
        self._queues_by_name = None          # _home_queue rebuilds lazily
        self._page_host = [p.path()[self._host_idx]
                           for p in self.topo.components("page")]
        self._dead_slots |= dead
        self._speed_by_host.pop(id(host), None)
        self._host_group.pop(id(host), None)

        # 5. restore-vs-reprefill: both paths produce the exact
        #    continuation (state, last token) into _kv_park; the quoted
        #    cost is billed at the orphan's re-admission
        bm = self.sched.bill_model
        restored = reprefilled = 0
        for req, t in orphans:
            m = len(req.out_tokens)
            assert m >= 1, "a resident request always holds >=1 token"
            reprefill_q = (len(req.prompt) + m - 1) * self.reprefill_unit
            snap = snaps.get(req.rid)
            usable = (snap is not None and 1 <= snap.emitted <= m
                      and (snap.emitted == m
                           or hasattr(self.backend, "replay")))
            restore_q = (bm.rebalance_move_cost(self.kv_restore_level,
                                                self.kv_bytes)
                         + (m - snap.emitted) * self.reprefill_unit) \
                if usable else float("inf")
            if restore_q < reprefill_q:
                assert int(snap.tok) == int(req.out_tokens[snap.emitted - 1])
                st = snap.state if snap.emitted == m else self.backend.replay(
                    snap.state, req.out_tokens[snap.emitted - 1:m - 1])
                debt = restore_q
                restored += 1
                self.stats.kv_restores += 1
            else:
                hist = req.prompt if m == 1 else np.concatenate(
                    [req.prompt, np.asarray(req.out_tokens[:-1], np.int32)])
                _, st = self.backend.prefill(hist)
                debt = reprefill_q
                reprefilled += 1
                self.stats.reprefills += 1
            self._kv_park[req.rid] = (st, int(req.out_tokens[-1]))
            self.stats.kv_parks += 1
            self._restore_debt[req.rid] = debt
            (gq if restart else fold).push(t)

        # 6. the exact rebalance quote re-deals the survivor fleet, billed
        #    from the first surviving slot (the fleet just changed shape —
        #    the skew trigger's window is stale by construction)
        movable, est = self.sched.estimate_rebalance("page", None)
        if movable >= 1:
            self._buy_redeal(next(self.topo.root.leaves()).cpu, now)
        self.stats.host_kills += 1
        self.stats.orphaned += len(orphans)
        return {"host": name, "orphaned": len(orphans),
                "restored": restored, "reprefilled": reprefilled,
                "requeued_pending": requeued, "queued_moved": moved_q,
                "redeal": movable >= 1, "redeal_quote": round(est, 4)}

    def join_host(self, name: Optional[str] = None, *,
                  slots: Optional[int] = None, speed: float = 1.0,
                  proactive: bool = True) -> str:
        """Grow the fleet by one host live — scale-out under load.

        The new host's slots join the hierarchy with fresh cpu ids, a
        fresh backend shard, zeroed HBM ledger entries per new page group,
        and its own decode-speed credit (``speed`` < 1 models a slow
        joiner exactly like ``host_speed``).  With ``proactive`` the
        engine quotes one machine-wide re-spread onto the new capacity
        against the expected cost of the joiner pulling its fair share
        one costed steal at a time (each dragging KV across the host
        boundary), and buys the deal only when the quote beats staying
        put — an unjustified joiner serves newly submitted work instead.
        ``name``, when given, must equal the name the topology assigns
        (names are monotone — a dead host's name is never reused).
        Returns the new host's name."""
        assert self.mode == "runtime", "join_host needs the runtime engine"
        assert self._host_idx is not None, \
            "single-host topology has no host level to grow"
        assert self.per_host_decode, "join_host needs per-host execution"
        assert 0.0 < speed <= 1.0, speed
        now = float(self.steps)
        n_new = int(slots) if slots is not None else \
            max(len(list(h.leaves())) for h in self.topo.components("host"))
        groups = max(-(-n_new // self._group), 1)
        b, r = divmod(n_new, groups)
        page_sizes = [b + 1] * r + [b] * (groups - r)
        host = self.topo.add_component("host", (groups, _fanout(page_sizes)))
        if name is not None:
            assert name == host.name, \
                f"topology assigned {host.name!r}, caller expected {name!r}"
        self.sched.queues.sync()
        self._queues_by_name = None
        lo = self.n_slots
        new_cpus = [leaf.cpu for leaf in host.leaves()]
        assert new_cpus == list(range(lo, lo + n_new)), new_cpus
        self.n_slots += n_new
        self._page_of.extend(self.topo.cpus[s].path()[self._page_idx].index
                             for s in new_cpus)
        max_page = max(p.index for p in self.topo.components("page"))
        self.hbm_used.extend(
            0.0 for _ in range(max_page + 1 - len(self.hbm_used)))
        self._page_host = [p.path()[self._host_idx]
                           for p in self.topo.components("page")]
        self._slot_charged.extend([False] * n_new)
        self._stall.extend([0.0] * n_new)
        self.slot_req.extend([None] * n_new)
        g_new = len(self._exec_groups)
        self._exec_groups.append((lo, lo + n_new))
        self._group_of.extend([g_new] * n_new)
        self._group_speed.append(float(speed))
        self._host_credit.append(0.0)
        self._host_group[id(host)] = g_new
        if self._speed_by_host or speed < 1.0:
            # keep the speed ruler total: hosts the engine never priced
            # run nominal.  (The scheduler only *consults* the ruler when
            # the engine was built speed_aware with host_speed; a slow
            # joiner on a speed-blind engine still executes slow — the
            # credit accumulator above — it is just not steered around.)
            for h in self.topo.components("host"):
                self._speed_by_host.setdefault(id(h), 1.0)
            self._speed_by_host[id(host)] = float(speed)
        st, tok = self.backend.init(n_new)
        self._states.append(st)
        self.tokens = np.concatenate([self.tokens, tok], axis=0)
        self.stats.host_decode_steps.append(0)
        self.stats.host_active_slots.append(0)
        self.stats.host_skipped_steps.append(0)
        self.stats.host_joins += 1
        if proactive:
            movable, est = self.sched.estimate_rebalance("page", None)
            if movable >= 1:
                # the steal path the deal replaces: the joiner pulls its
                # fair share of the backlog one costed host-crossing
                # steal at a time, each dragging one request's KV
                cm = self.sched.cost_model
                share = movable * n_new / max(len(self.topo.live_cpus()), 1)
                src = next((p for p in self.topo.components("page")
                            if self.topo.ancestor_at(p, "host") is not host),
                           None)
                per_steal = cm.steal_cost(
                    self.topo.levels_crossed(lo, src), 1, "host",
                    self.kv_bytes) if src is not None else 0.0
                if est < share * per_steal:
                    self._buy_redeal(lo, now)
        return host.name

    # -- introspection ---------------------------------------------------------
    def counters(self) -> dict:
        """Engine + scheduler ledger in one dict (benchmark rows)."""
        s = self.sched.stats
        out = {
            "steps": self.steps,
            "steals": s.steals, "steal_attempts": s.steal_attempts,
            "steal_refusals": s.steal_refusals,
            "steal_cost": round(s.steal_cost, 4),
            "rebalances": s.rebalances,
            "rebalance_moves": s.rebalance_moves,
            "data_migrations": self.runtime.data_migrations,
            "kv_migrations": self.stats.kv_migrations,
            "kv_page_moves": self.stats.kv_page_moves,
            "kv_host_moves": self.stats.kv_host_moves,
            "kv_splices": self.stats.kv_splices,
            "kv_spliced_slots": self.stats.kv_spliced_slots,
            "kv_parks": self.stats.kv_parks,
            "prefills": self.stats.prefills,
            "prefill_waves": self.stats.prefill_waves,
            "local_rebalances": self.stats.local_rebalances,
            "stall_steps": round(self.stats.stall_steps, 4),
            "hbm_slot_waits": self.stats.hbm_slot_waits,
            "hbm_refusals": self.stats.hbm_refusals,
            "gang_splits": self.stats.gang_splits,
            "gang_split_members": self.stats.gang_split_members,
            "preemptions": self.stats.preemptions,
            "preempt_parks": self.stats.preempt_parks,
            "demotions": self.stats.demotions,
            "host_decode_steps": list(self.stats.host_decode_steps),
            "host_active_slots": list(self.stats.host_active_slots),
            "host_skipped_steps": list(self.stats.host_skipped_steps),
            # effective per-host throughput: decoded slot-tokens per
            # engine step — what a straggler actually delivers
            "host_throughput": [
                round(a / max(self.steps, 1), 4)
                for a in self.stats.host_active_slots],
        }
        if self.stats.host_kills or self.stats.host_joins:
            # elastic ledger: keyed only when the fleet actually changed
            # shape, so every pre-elastic benchmark row stays bit-identical
            out.update({
                "host_kills": self.stats.host_kills,
                "host_joins": self.stats.host_joins,
                "orphaned": self.stats.orphaned,
                "kv_restores": self.stats.kv_restores,
                "reprefills": self.stats.reprefills,
            })
        if self.stats.sleeps or self.stats.holds:
            # agentic ledger: keyed only when a tool call actually fired,
            # so every pre-agentic benchmark row stays bit-identical
            out.update({
                "sleeps": self.stats.sleeps,
                "holds": self.stats.holds,
                "hold_slot_steps": self.stats.hold_slot_steps,
                "wakes": self.stats.wakes,
                "wake_home": self.stats.wake_home,
                "wake_away": self.stats.wake_away,
                "wake_reprefills": self.stats.wake_reprefills,
                "stale_evictions": self.stats.stale_evictions,
            })
        return out
