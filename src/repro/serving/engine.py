"""Serving engine: continuous batching as the second SchedulerRuntime client.

Requests are *threads* (work = tokens still to decode, data = the gang's KV
page-group id); requests sharing a prompt prefix or an SLA class are grouped
into *bubbles*.  The engine owns a fixed-size decode batch and maps it onto
the scheduling model exactly as the paper prescribes for any workload:

=================  ==========================================================
scheduler concept  serving meaning
=================  ==========================================================
cpu (leaf)         decode batch slot
level              KV page group (``page``): slots sharing a cache page
data object        a gang's KV state (``Thread.data`` = gang id)
steal              an idle slot pulls a queued gang from a loaded page group
next touch         first post-migration admission re-homes the gang's KV via
                   a *batched* splice of parked per-request states — not the
                   old per-request re-prefill path
rebalance          queue-depth skew across page groups triggers one bulk
                   LPT re-spread (`BubbleScheduler.rebalance`), cost-gated
=================  ==========================================================

The engine drives the same :class:`~repro.core.runtime.SchedulerRuntime`
loop as the discrete simulator — ``acquire`` (lookup + steal + cost
billing), ``touch`` (first/next-touch KV homing), ``rebalance_worth_it``
(the AdaptivePolicy-style cost-benefit trigger, fed by decode-gang queue
depths instead of steal-attempt windows).  ``mode="admission"`` keeps the
pre-runtime behaviour (no steal, no rebalance, first-touch homing) as the
measurable baseline for ``benchmarks/serve_gangs.py``.

Cost has a physical meaning here: a :class:`StealCostModel` penalty accrued
by a slot's scheduler call (remote page-group locks, KV drag) is billed as
*admission-latency steps* — the slot sits out that many engine steps before
its next decode, so steal-happy schedules pay for their migrations in the
engine's own currency.

The decode loop itself is one jitted ``decode_step`` over the whole batch;
slot occupancy is a boolean mask (empty slots decode padding at negligible
marginal cost on TPU).  The model is behind a two-method backend so the
scheduler stack can be exercised hermetically: :class:`JaxModelBackend`
runs the real zoo, :class:`StubModelBackend` is a deterministic numpy
stand-in (no jit compile) for tests and CI benchmarks.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.bubble import Bubble, Thread, bubble, thread
from repro.core.policies import BubblePolicy, StealPolicy
from repro.core.runtime import SchedulerRuntime
from repro.core.scheduler import StealCostModel
from repro.core.topology import Level, Topology

# The serving price list: a steal pays remote page-group lock traffic plus a
# per-level / per-request KV drag, a rebalance pays one bulk charge — all in
# engine steps (admission latency).  Small relative to typical decode
# lengths, so stealing stays profitable but not free; the queue-depth
# rebalance trigger needs the nonzero prices to pass its cost-benefit test.
SERVE_COST = StealCostModel(lock_penalty=0.5, level_penalty=0.25,
                            thread_penalty=0.125, rebalance_base=1.0,
                            rebalance_per_move=0.125)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    prio: int = 0
    gang: Optional[str] = None         # co-schedule group (shared prefix)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    """Engine-side ledger (scheduler counters live in ``sched.stats``)."""

    prefills: int = 0            # fresh prompt prefills run
    kv_splices: int = 0          # batched splice ops issued
    kv_spliced_slots: int = 0    # slots written by those splices
    kv_parks: int = 0            # per-request KV states parked
    kv_migrations: int = 0       # next-touch re-homes of a gang's KV
    kv_page_moves: int = 0       # ...of which crossed page groups
    rebalances: int = 0          # queue-depth-triggered re-spreads
    stall_steps: float = 0.0     # admission latency billed by the cost model


def slots_topology(n_slots: int, group: int = 4) -> Topology:
    """Model the decode batch as a tiny hierarchy: slot groups share a KV
    page (affinity level), slots are the leaves.

    ``n_slots`` need not divide evenly: the remainder is distributed so
    group sizes differ by at most one and **every** slot is a schedulable
    leaf (the old ``n_slots // group`` derivation silently dropped the
    remainder — ``n_slots=9, group=4`` built 2x4 leaves and slot 8 could
    never be admitted to)."""
    assert n_slots >= 1, n_slots
    groups = max(-(-n_slots // group), 1)             # ceil division
    base, rem = divmod(n_slots, groups)
    sizes = [base + 1] * rem + [base] * (groups - rem)
    fanout = sizes[0] if len(set(sizes)) == 1 else sizes
    return Topology([
        Level("batch", 1),
        Level("page", groups, factor=2.0),
        Level("slot", fanout),
    ])


# ---------------------------------------------------------------------------
# model backends
# ---------------------------------------------------------------------------

class JaxModelBackend:
    """The real model zoo: jitted whole-batch decode + per-request prefill.

    State leaves carry the batch at axis 1 (layer-major), matching
    ``api.lm.init_state``; splice/extract address that axis."""

    def __init__(self, cfg, params, cache_len: int):
        import jax  # deferred: stub-mode users never pay the import
        from repro.models import api
        self._jax = jax
        self._api = api
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self._decode = jax.jit(api.make_decode_fn(cfg))
        self._prefill = api.make_prefill_fn(cfg, cache_len)

    def init(self, n_slots: int) -> tuple:
        states = self._api.lm.init_state(self.cfg, n_slots, self.cache_len)
        return states, np.zeros((n_slots, 1), np.int32)

    def prefill(self, prompt: np.ndarray) -> tuple[int, object]:
        jnp = self._jax.numpy
        logits, st = self._prefill(self.params, {"tokens":
                                                 jnp.asarray(prompt[None, :])})
        tok = int(jnp.argmax(logits, axis=-1).astype(jnp.int32)[0])
        return tok, st

    def decode(self, tokens: np.ndarray, states) -> tuple[np.ndarray, object]:
        jnp = self._jax.numpy
        logits, states = self._decode(self.params, jnp.asarray(tokens), states)
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # (B,)
        return next_tok, states

    def splice(self, states, pairs: list[tuple[int, object]]):
        """Write several single-sequence states into their batch slots in
        ONE traversal — the batched next-touch splice (the old engine
        spliced once per request)."""
        jnp = self._jax.numpy
        slots = jnp.asarray([s for s, _ in pairs])

        def write(b, *ones):
            if b.ndim < 2:
                return b
            return b.at[:, slots].set(jnp.concatenate(ones, axis=1))

        return self._jax.tree.map(write, states, *[st for _, st in pairs])

    def extract(self, states, slot: int):
        return self._jax.tree.map(
            lambda b: b[:, slot:slot + 1] if b.ndim >= 2 else b, states)


class StubModelBackend:
    """Deterministic numpy decode/prefill stand-in — no jax, no jit.

    Each slot's "KV state" is ``(position, history_hash)``; the next token
    is a function of the full token history, so any KV mishandling (a lost
    splice, a stale slot, a wrong-slot write) changes the output stream and
    is caught by equality tests.  This is what tests and the CI serving
    benchmark run: the scheduler stack is identical, only the model is
    stubbed."""

    M = 2_147_483_647                 # hash modulus (prime, fits int64)

    def __init__(self, vocab: int = 251):
        self.vocab = vocab

    def init(self, n_slots: int) -> tuple[np.ndarray, np.ndarray]:
        return (np.zeros((n_slots, 2), np.int64),
                np.zeros((n_slots, 1), np.int32))

    def _fold(self, acc: int, tok: int) -> int:
        return (acc * 31 + int(tok) + 1) % self.M

    def prefill(self, prompt: np.ndarray) -> tuple[int, np.ndarray]:
        acc = 0
        for tok in np.asarray(prompt).ravel():
            acc = self._fold(acc, tok)
        return acc % self.vocab, np.array([len(prompt), acc], np.int64)

    def decode(self, tokens: np.ndarray, states: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
        acc = (states[:, 1] * 31 + tokens[:, 0].astype(np.int64) + 1) % self.M
        out = np.stack([states[:, 0] + 1, acc], axis=1)
        return (acc % self.vocab).astype(np.int32), out

    def splice(self, states: np.ndarray, pairs: list[tuple[int, np.ndarray]]
               ) -> np.ndarray:
        states = states.copy()
        for slot, row in pairs:
            states[slot] = row
        return states

    def extract(self, states: np.ndarray, slot: int) -> np.ndarray:
        return states[slot].copy()


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServingEngine:
    """Continuous batching driven by the shared scheduler runtime.

    * a gang (bubble) bursts only when enough slots are free to co-schedule
      it (priorities implement the paper's gang scheduling — Figure 1);
    * prefix-affine requests land in adjacent slots so their shared KV
      prefix stays resident (the data-sharing relation);
    * a starving slot's ``acquire`` runs the hierarchical steal pass — a
      queued gang is pulled whole from a loaded page group, its threads
      flagged for next-touch so the first post-migration admission re-homes
      their KV (batched splice), and the thief pays the cost model's
      admission-latency bill;
    * page-group queue-depth skew feeds the runtime's cost-benefit test and
      triggers one bulk ``rebalance`` when recent steal spend exceeds the
      re-spread bill;
    * a request group that stalls (client backpressure) is *regenerated*:
      pulled out of the slots — its per-slot KV parked — and re-queued as a
      closed bubble, keeping its affinity.

    ``mode="admission"`` is the pre-runtime engine: plain admission, no
    steal, no rebalance, first-touch homing.
    """

    def __init__(self, cfg, params, *, n_slots: int = 8,
                 cache_len: int = 256, group: int = 4,
                 backend=None, mode: str = "runtime",
                 cost_model: StealCostModel = SERVE_COST,
                 depth_skew: int = 2, window: int = 16,
                 min_backlog: int = 2, cooldown: Optional[int] = None):
        assert mode in ("runtime", "admission"), mode
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.mode = mode
        self.topo = slots_topology(n_slots, group)
        if mode == "runtime":
            self.policy = StealPolicy(self.topo, cost_model=cost_model)
        else:
            self.policy = BubblePolicy(self.topo, steal=False)
        self.sched = self.policy.sched
        self.runtime = SchedulerRuntime(self.topo, self.policy,
                                        on_data_migrate=self._on_kv_migrate)
        self.backend = backend if backend is not None else \
            JaxModelBackend(cfg, params, cache_len)
        self.states, self.tokens = self.backend.init(n_slots)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_thread: dict[int, Thread] = {}
        self._reqs: dict[int, Request] = {}
        self._gangs: dict[str, Bubble] = {}
        self._next_rid = 0
        self._kv_park: dict[int, tuple[object, int]] = {}  # rid -> (state, tok)
        self._stall = [0.0] * n_slots     # admission-latency bill per slot
        self._pending: dict[int, Thread] = {}  # claimed, waiting out a stall
        # queue-depth rebalance trigger state (runtime mode only)
        self.depth_skew = depth_skew
        self.min_backlog = min_backlog
        self.window = window
        self.cooldown = window if cooldown is None else cooldown
        self._paid: deque[float] = deque()        # steal cost per step
        self._steps_since_rebalance = self.cooldown   # start armed
        self._cost_mark = 0.0
        self.stats = EngineStats()
        self.steps = 0
        self.completed: list[Request] = []

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               prio: int = 0, gang: Optional[str] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                      prio=prio, gang=gang)
        self._reqs[rid] = req
        t = thread(float(max_new_tokens), name=f"req{rid}", prio=prio,
                   data=gang or f"req{rid}")
        t.request = req                                   # type: ignore
        if gang is None:
            self.sched.submit_thread(t)
            return rid
        g = self._gang_bubble(gang, prio)
        g.insert(t)
        if g.burst:
            # the gang already burst: late joiners land on the list where
            # it burst (its scheduling area) — inserting into an off-queue
            # burst husk would strand them forever
            q = g.home_list if g.home_list is not None \
                else self.sched.queues.global_queue()
            q.push(t)
        elif not self._gang_scheduled(g):
            # fresh gang, or one that completed/was dropped and has new
            # members: (re-)wake it.  The old engine set a sticky ``_woken``
            # flag here, so a finished gang's bubble could never be woken
            # again and later submits to the same gang were lost.
            self.sched.wake_up_bubble(g)
        return rid

    def _gang_bubble(self, gang: str, prio: int) -> Bubble:
        key = f"gang:{gang}"
        b = self._gangs.get(key)
        if b is None:
            # gang bubbles less prioritised than their threads => they burst
            # only when running threads can't fill the slots (Figure 1)
            b = bubble(name=key, prio=prio - 1, burst_level="page")
            self._gangs[key] = b
        return b

    def _gang_scheduled(self, g: Bubble) -> bool:
        """Whether the scheduler still owns the gang: the closed bubble (or
        any of its tasks) sits on some list, or a member occupies a slot."""
        for q in self.sched.queues.queues.values():
            for task in q.tasks:
                if task is g or task.root() is g:
                    return True
        return any(t.parent is g for t in self.slot_thread.values()) or \
            any(t.parent is g for t in self._pending.values())

    # -- KV homing (the data policy's physical side) --------------------------
    def _on_kv_migrate(self, data: str, old_slot: int, new_slot: int) -> None:
        self.stats.kv_migrations += 1
        if self.topo.common_level(old_slot, new_slot).name == "batch":
            self.stats.kv_page_moves += 1      # crossed KV page groups

    # -- slot management ------------------------------------------------------
    def _admit(self, now: float) -> None:
        """Fill free slots from the runtime; batch every KV write.

        Parked requests (regenerated, possibly stolen meanwhile) are
        restored with a *splice* of their saved state — the next-touch
        re-home — instead of a re-prefill; fresh requests run prefill.
        All resulting single-slot states are written in one batched
        splice at the end.

        A scheduler call that accrued cost (a successful steal's remote
        lock/KV drag) stalls its slot: the claimed thread waits in
        ``_pending`` and enters the slot only once the admission-latency
        bill is paid — the slot never holds a half-migrated request whose
        state the whole-batch decode would advance."""
        writes: list[tuple[int, object]] = []
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or self._stall[slot] > 0:
                continue
            t = self._pending.pop(slot, None)
            if t is None:
                t, cost = self.runtime.acquire(slot, now)
                if cost:
                    self._stall[slot] += cost
                    self.stats.stall_steps += cost
                if t is None:
                    continue
                if t.remaining <= 0 or t.request.done:    # stale: drop
                    self.runtime.release(slot, t, True, now)
                    continue
                if self._stall[slot] > 0:     # pay the migration first
                    self._pending[slot] = t
                    continue
            req: Request = t.request                      # type: ignore
            self.slot_req[slot] = req
            self.slot_thread[slot] = t
            # data policy: first/next-touch homing of the gang's KV pages
            self.runtime.touch(slot, t)
            parked = self._kv_park.pop(req.rid, None)
            if parked is not None:
                st, tok = parked
                self.tokens[slot, 0] = tok    # resume the continuation
            else:
                tok, st = self.backend.prefill(req.prompt)
                req.out_tokens.append(tok)
                self.tokens[slot, 0] = tok
                self.stats.prefills += 1
            writes.append((slot, st))
        if writes:
            self.states = self.backend.splice(self.states, writes)
            self.stats.kv_splices += 1
            self.stats.kv_spliced_slots += len(writes)

    def _evict(self, slot: int, now: float) -> None:
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            self.completed.append(req)
        self.slot_req[slot] = None
        t = self.slot_thread.pop(slot, None)
        if t is not None:
            # the prefill token counts toward max_new_tokens but never
            # decremented `remaining`; zero it so a later gang regeneration
            # cannot resurrect the finished thread
            t.remaining = 0.0
            self.runtime.release(slot, t, True, now)
        self.tokens[slot, 0] = 0              # freed slot: no stale decode

    # -- queue-depth rebalance trigger ----------------------------------------
    def _page_depths(self) -> list[int]:
        """Runnable decode threads pinned under each page group's lists
        (work on the global list is reachable by every slot and is not
        skew)."""
        depths = []
        for comp in self.topo.components("page"):
            n = 0
            for sub in self.sched._bfs(comp):
                for task in self.sched.queues.queue_of(sub).tasks:
                    if isinstance(task, Bubble):
                        n += sum(1 for th in task.threads()
                                 if th.remaining > 0)
                    elif task.remaining > 0:
                        n += 1
            depths.append(n)
        return depths

    def _maybe_rebalance(self, now: float) -> None:
        """Decode-gang queue depths feed the same cost-benefit test the
        adaptive simulator policy uses: when one page group's backlog
        outruns another's by ``depth_skew`` and the steal cost recently
        paid exceeds one bulk re-spread's bill, re-spread across the page
        groups instead of letting slots drain the skew one costed steal at
        a time."""
        if self.mode != "runtime":
            return
        s = self.sched.stats
        self._paid.append(s.steal_cost - self._cost_mark)
        self._cost_mark = s.steal_cost
        if len(self._paid) > self.window:
            self._paid.popleft()
        self._steps_since_rebalance += 1
        if self._steps_since_rebalance < self.cooldown:
            return
        depths = self._page_depths()
        if len(depths) < 2 or max(depths) - min(depths) < self.depth_skew:
            return
        if not self.runtime.rebalance_worth_it(sum(self._paid),
                                               min_backlog=self.min_backlog,
                                               level="page"):
            return
        # bill the re-spread to (a slot of) the emptiest page group — the
        # one whose starvation triggered it.  The scheduler accrues the
        # cost for its *next* consume_cost() caller, which outside an
        # acquire would be an arbitrary slot; drain it here and stall the
        # triggering slot explicitly instead.
        page = min(range(len(depths)), key=depths.__getitem__)
        slot = next(iter(self.topo.components("page")[page].leaves())).cpu
        self.runtime.rebalance(slot, now, level="page")
        cost = self.policy.consume_cost()
        if cost:
            self._stall[slot] += cost
            self.stats.stall_steps += cost
        self.stats.rebalances += 1
        self._paid.clear()
        self._cost_mark = self.sched.stats.steal_cost
        self._steps_since_rebalance = 0

    # -- the decode loop -------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: consider a rebalance, admit, decode one
        token for every occupied unstalled slot, retire finished requests.
        Returns #slots decoded."""
        now = float(self.steps)
        self.steps += 1
        self._maybe_rebalance(now)
        self._admit(now)
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        for s in range(self.n_slots):
            if self._stall[s] > 0:
                self._stall[s] = max(0.0, self._stall[s] - 1.0)
        if not active:
            return 0
        next_tok, self.states = self.backend.decode(self.tokens, self.states)
        for s in active:
            self.tokens[s, 0] = next_tok[s]
            req = self.slot_req[s]
            req.out_tokens.append(int(next_tok[s]))
            t = self.slot_thread[s]
            t.remaining -= 1.0
            if len(req.out_tokens) >= req.max_new_tokens:
                self._evict(s, now)
        return len(active)

    def _drained(self) -> bool:
        return (not any(self.slot_req) and not self._pending
                and self.sched.queues.total_tasks() == 0
                and not any(st > 0 for st in self._stall))

    def run(self, max_steps: int = 1000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if self._drained():
                break
        return self.completed

    # -- regeneration (backpressure / straggling client) ------------------------
    def regenerate_gang(self, gang: str) -> int:
        """Pull a gang's requests out of the slots — parking each slot's KV
        state and last token so the later re-admission resumes the
        continuation via the batched splice — and re-queue the closed
        bubble (affinity preserved).

        The old engine left the freed slots' tokens and the popped threads'
        running state behind: a re-queued gang decoded from stale tokens
        and could never be woken again once finished."""
        b = self._gangs.get(f"gang:{gang}")
        if b is None:
            return 0
        now = float(self.steps)
        # a member claimed into _pending (waiting out its steal stall) goes
        # back into the bubble: the regenerated gang re-pushes it at its
        # next burst, and leaving it pending too would double-schedule it
        for s, t in list(self._pending.items()):
            if t.parent is b:
                del self._pending[s]
                self.runtime.release(s, t, False, now)
        n = 0
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is not None and req.gang == gang and not req.done:
                t = self.slot_thread.pop(s)
                self.slot_req[s] = None
                self._kv_park[req.rid] = (self.backend.extract(self.states, s),
                                          int(self.tokens[s, 0]))
                self.stats.kv_parks += 1
                self.tokens[s, 0] = 0
                self.runtime.release(s, t, False, now)
                n += 1
        self.sched.regenerate(b, running={})
        return n

    # -- introspection ---------------------------------------------------------
    def counters(self) -> dict:
        """Engine + scheduler ledger in one dict (benchmark rows)."""
        s = self.sched.stats
        return {
            "steps": self.steps,
            "steals": s.steals, "steal_attempts": s.steal_attempts,
            "steal_cost": round(s.steal_cost, 4),
            "rebalances": s.rebalances,
            "rebalance_moves": s.rebalance_moves,
            "data_migrations": self.runtime.data_migrations,
            "kv_migrations": self.stats.kv_migrations,
            "kv_page_moves": self.stats.kv_page_moves,
            "kv_splices": self.stats.kv_splices,
            "kv_spliced_slots": self.stats.kv_spliced_slots,
            "kv_parks": self.stats.kv_parks,
            "prefills": self.stats.prefills,
            "stall_steps": round(self.stats.stall_steps, 4),
        }
