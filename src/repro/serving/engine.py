"""Serving engine: continuous batching driven by the bubble scheduler.

Requests are *threads* (work = tokens still to decode, data = prefix-cache
id); requests sharing a prompt prefix or an SLA class are grouped into
*bubbles*.  The engine owns a fixed-size decode batch (the "processors" of
the scheduling problem are batch slots); whenever slots free up, it calls
the bubble scheduler exactly like a cpu calling Marcel's schedule function:

* a gang (bubble) bursts only when enough slots are free to co-schedule it
  (priorities implement the paper's gang scheduling — Figure 1);
* prefix-affine requests land in adjacent slots so their shared KV prefix
  stays resident (the data-sharing relation);
* a request group that stalls (client backpressure) is regenerated: pulled
  out of the slots and re-queued as a closed bubble, keeping its affinity.

The decode loop itself is one jitted ``decode_step`` over the whole batch;
slot occupancy is a boolean mask (empty slots decode padding at negligible
marginal cost on TPU).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bubble import Bubble, Thread, bubble, thread
from repro.core.scheduler import BubbleScheduler
from repro.core.topology import Level, Topology
from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    prio: int = 0
    gang: Optional[str] = None         # co-schedule group (shared prefix)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def slots_topology(n_slots: int, group: int = 4) -> Topology:
    """Model the decode batch as a tiny hierarchy: slot groups share a KV
    page (affinity level), slots are the leaves."""
    groups = max(n_slots // group, 1)
    return Topology([
        Level("batch", 1),
        Level("page", groups, factor=2.0),
        Level("slot", n_slots // groups),
    ])


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.sched = BubbleScheduler(slots_topology(n_slots))
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_thread: dict[int, Thread] = {}
        self._reqs: dict[int, Request] = {}
        self._next_rid = 0
        self.states = api.lm.init_state(cfg, n_slots, cache_len)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode = jax.jit(api.make_decode_fn(cfg))
        self._prefill_cache = {}
        self.steps = 0
        self.completed: list[Request] = []

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               prio: int = 0, gang: Optional[str] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                      prio=prio, gang=gang)
        self._reqs[rid] = req
        t = thread(float(max_new_tokens), name=f"req{rid}", prio=prio,
                   data=gang or f"req{rid}")
        t.request = req                                   # type: ignore
        if gang is not None:
            g = self._gang_bubble(gang, prio)
            g.insert(t)
            if not getattr(g, "_woken", False):
                self.sched.wake_up_bubble(g)
                g._woken = True                           # type: ignore
        else:
            self.sched.submit_thread(t)
        return rid

    def _gang_bubble(self, gang: str, prio: int) -> Bubble:
        key = f"gang:{gang}"
        b = getattr(self, "_gangs", {}).get(key)
        if b is None:
            if not hasattr(self, "_gangs"):
                self._gangs = {}
            # gang bubbles less prioritised than their threads => they burst
            # only when running threads can't fill the slots (Figure 1)
            b = bubble(name=key, prio=prio - 1, burst_level="page")
            self._gangs[key] = b
        return b

    # -- slot management ------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None:
                continue
            t = self.sched.next_thread(slot)
            if t is None:
                return
            req: Request = t.request                      # type: ignore
            self.slot_req[slot] = req
            self.slot_thread[slot] = t
            self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Run prefill for one request and splice its state into the batch
        state at ``slot``."""
        prompt = jnp.asarray(req.prompt[None, :])         # (1, S)
        logits, st = api.make_prefill_fn(self.cfg, self.cache_len)(
            self.params, {"tokens": prompt})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (1,)
        req.out_tokens.append(int(tok[0]))
        self.tokens = self.tokens.at[slot, 0].set(tok[0])
        self.states = _splice_states(self.states, st, slot)

    def _evict(self, slot: int) -> None:
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            self.completed.append(req)
        self.slot_req[slot] = None
        self.slot_thread.pop(slot, None)

    # -- the decode loop -------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, decode one token for every occupied
        slot, retire finished requests.  Returns #active slots."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return 0
        logits, self.states = self._decode(self.params, self.tokens,
                                           self.states)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B,)
        self.tokens = next_tok[:, None]
        self.steps += 1
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(next_tok[s]))
            t = self.slot_thread[s]
            t.remaining -= 1.0
            if len(req.out_tokens) >= req.max_new_tokens:
                self._evict(s)
        return len(active)

    def run(self, max_steps: int = 1000) -> list[Request]:
        for _ in range(max_steps):
            busy = self.step()
            if busy == 0 and self.sched.queues.total_tasks() == 0:
                break
        return self.completed

    # -- regeneration (backpressure / straggling client) ------------------------
    def regenerate_gang(self, gang: str) -> int:
        """Pull a gang's requests out of the slots; re-queue the closed
        bubble (affinity preserved)."""
        b = getattr(self, "_gangs", {}).get(f"gang:{gang}")
        if b is None:
            return 0
        n = 0
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is not None and req.gang == gang and not req.done:
                self.slot_req[s] = None
                t = self.slot_thread.pop(s)
                n += 1
        self.sched.regenerate(b, running={})
        return n


def _splice_states(batch_states, one_states, slot: int):
    """Write a single-sequence decode state into batch position ``slot``."""
    def splice(b, o):
        return b.at[:, slot:slot + 1].set(o) if b.ndim >= 2 else b
    return jax.tree.map(splice, batch_states, one_states)
