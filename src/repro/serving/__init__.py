from .engine import (FLAT_SERVE_COST, SERVE_COST, EngineStats,
                     JaxModelBackend, Request, ServingEngine,
                     StubModelBackend, slots_topology)

__all__ = ["Request", "ServingEngine", "slots_topology", "SERVE_COST",
           "FLAT_SERVE_COST", "EngineStats", "JaxModelBackend",
           "StubModelBackend"]
