from .engine import (SERVE_COST, EngineStats, JaxModelBackend, Request,
                     ServingEngine, StubModelBackend, slots_topology)

__all__ = ["Request", "ServingEngine", "slots_topology", "SERVE_COST",
           "EngineStats", "JaxModelBackend", "StubModelBackend"]
