from .engine import Request, ServingEngine, slots_topology

__all__ = ["Request", "ServingEngine", "slots_topology"]
