from .engine import (BW_SERVE_COST, FLAT_SERVE_COST, SERVE_COST,
                     SERVE_FREE_LEVELS, EngineStats, JaxModelBackend,
                     PagedJaxModelBackend, Request, ServingEngine,
                     SleepingLedger, StubModelBackend, slots_topology)
from .workload import (SLA_CLASSES, OpenRequest, SLAClass, bursty_arrivals,
                       diurnal_arrivals, drive, goodput_under_sla,
                       make_agentic_trace, make_trace, percentile,
                       poisson_arrivals)

__all__ = ["Request", "ServingEngine", "slots_topology", "SERVE_COST",
           "FLAT_SERVE_COST", "BW_SERVE_COST", "SERVE_FREE_LEVELS",
           "EngineStats", "JaxModelBackend", "SleepingLedger",
           "PagedJaxModelBackend", "StubModelBackend", "SLAClass", "SLA_CLASSES", "OpenRequest",
           "poisson_arrivals", "bursty_arrivals", "diurnal_arrivals",
           "make_trace", "make_agentic_trace", "drive", "goodput_under_sla",
           "percentile"]
