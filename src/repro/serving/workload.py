"""Open-loop serving workload layer: arrivals, length mixes, SLA classes.

Every earlier ``serve/`` benchmark drained a *closed* batch of gangs to
completion — throughput in engine steps, nothing about what an arriving
user feels.  This module is the open-loop side: requests arrive on their
own clock (the engine does not control the arrival rate), each stamped
with its submit step and an SLA class, and the engine is measured on
**arrival-time latency** — TTFT and per-token percentiles per class, and
goodput-under-SLA.

Three arrival processes, all deterministic under a seed:

* :func:`poisson_arrivals` — memoryless open-loop load (per-step counts
  drawn Poisson at a constant rate);
* :func:`bursty_arrivals` — an on/off modulated Poisson (bursts at
  ``rate_on`` separated by quiet ``rate_off`` stretches) — the shape that
  exposes admission-path bugs a steady rate hides;
* :func:`diurnal_arrivals` — a sinusoidally modulated rate (a scaled-down
  day/night traffic trace).

Request sizes are **heavy-tailed** (clipped lognormal): most prompts and
decodes are short, a fat tail is not — the tail is what the multilevel-
feedback demotion in the engine exists for.

The SLA classes map straight onto the paper's priority mechanism
(§3.3.2: cpus run the highest-priority task among covering lists, even
when less-prioritised work is more local):

==============  =====================  ====================================
SLA class       paper priority         engine knob
==============  =====================  ====================================
``interactive`` ``prio=2`` (highest)   ``preempts=True``: backlog may park
                                       a ``batch`` gang's KV to get a slot
``standard``    ``prio=1``             WDRR ``weight=3``; demotes to
                                       ``batch`` past ``demote_after``
``batch``       ``prio=0`` (lowest)    WDRR ``weight=1``;
                                       ``preemptible=True``: parked via the
                                       KV park/splice path, resumed without
                                       re-prefill
==============  =====================  ====================================

Priorities alone would starve ``batch`` under sustained ``interactive``
load, so admission is a weighted **deficit round-robin** across the
classes (the weighted-round-robin scheme schedsi's TODO list points at as
"the basis of the most popular general purpose OS schedulers"), mapped
onto the existing covering-list walk via a task filter: a class out of
credit becomes invisible to the walk until every backlogged class has
spent its quantum (then a new round replenishes each by its weight), and
unused capacity always spills to whoever has work (work-conserving).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = ["SLAClass", "SLA_CLASSES", "OpenRequest", "poisson_arrivals",
           "bursty_arrivals", "diurnal_arrivals", "make_trace",
           "make_agentic_trace", "drive", "goodput_under_sla", "percentile"]


# ---------------------------------------------------------------------------
# SLA classes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLAClass:
    """One SLA tier: the paper priority it maps onto plus the engine knobs.

    ``prio`` is the §3.3.2 priority the class's threads carry; ``weight``
    the WDRR quantum (slots per admission round while backlogged);
    ``ttft_slo`` the goodput gate in engine steps (a completed request
    counts as *good* when its TTFT is within the SLO; ``None`` = no TTFT
    bound, completion alone is good — the batch contract).
    ``demote_after``/``demote_to`` is the multilevel-feedback rule: a
    request that has decoded that many tokens stops being a short
    interactive job by definition and sinks a tier.  ``preempts`` marks a
    class whose backlog may trigger a preemption; ``preemptible`` a class
    whose gangs may be parked (KV park/splice) to make room."""

    name: str
    prio: int
    weight: int
    ttft_slo: Optional[int] = None
    demote_after: Optional[int] = None
    demote_to: Optional[str] = None
    preempts: bool = False
    preemptible: bool = False


SLA_CLASSES: dict[str, SLAClass] = {
    "interactive": SLAClass("interactive", prio=2, weight=8, ttft_slo=8,
                            demote_after=24, demote_to="standard",
                            preempts=True),
    "standard": SLAClass("standard", prio=1, weight=3, ttft_slo=24,
                         demote_after=96, demote_to="batch"),
    "batch": SLAClass("batch", prio=0, weight=1, ttft_slo=None,
                      preemptible=True),
}

# per-class request-shape mix: (share of arrivals, prompt-length lognormal
# (mean, sigma, lo, hi), decode-length lognormal (mean, sigma, lo, hi),
# gang size (batch requests arrive as prefix-affine gangs))
_MIX = {
    "interactive": (0.45, (2.0, 0.5, 4, 24), (1.7, 0.5, 2, 16), 1),
    "standard": (0.35, (2.3, 0.6, 4, 32), (2.4, 0.6, 4, 32), 1),
    "batch": (0.20, (2.3, 0.6, 4, 32), (3.2, 0.5, 12, 64), 4),
}


@dataclasses.dataclass
class OpenRequest:
    """One arrival of the open-loop trace, stamped with its submit step."""

    step: int                      # engine step the request arrives at
    sla: str                       # SLA class name
    prompt: np.ndarray             # (S,) int32 token ids
    new_tokens: int                # decode length
    gang: Optional[str] = None     # prefix-affine group (batch tiers)
    tool_calls: tuple = ()         # ((at_tokens, think_steps), ...) markers


# ---------------------------------------------------------------------------
# arrival processes (per-step arrival counts, deterministic under a seed)
# ---------------------------------------------------------------------------

def poisson_arrivals(rate: float, steps: int, rng) -> list[int]:
    """Constant-rate open-loop arrivals: counts[t] ~ Poisson(rate)."""
    assert rate >= 0.0 and steps >= 0, (rate, steps)
    return [int(n) for n in rng.poisson(rate, size=steps)]


def bursty_arrivals(rate_on: float, rate_off: float, on_len: int,
                    off_len: int, steps: int, rng) -> list[int]:
    """On/off modulated Poisson: ``on_len`` steps at ``rate_on``, then
    ``off_len`` at ``rate_off``, repeating — the bursty shape that piles a
    backlog onto the admission path all at once."""
    assert on_len >= 1 and off_len >= 0, (on_len, off_len)
    period = on_len + off_len
    rates = [rate_on if (t % period) < on_len else rate_off
             for t in range(steps)]
    return [int(rng.poisson(r)) for r in rates]


def diurnal_arrivals(base: float, amplitude: float, period: int,
                     steps: int, rng) -> list[int]:
    """Sinusoidally modulated Poisson (a scaled-down day/night trace):
    rate(t) = max(0, base + amplitude * sin(2*pi*t/period))."""
    assert period >= 1, period
    rates = [max(0.0, base + amplitude * math.sin(2 * math.pi * t / period))
             for t in range(steps)]
    return [int(rng.poisson(r)) for r in rates]


def _length(rng, mean: float, sigma: float, lo: int, hi: int) -> int:
    """Clipped-lognormal integer length — heavy-tailed by construction."""
    return int(min(hi, max(lo, round(float(rng.lognormal(mean, sigma))))))


def make_trace(*, steps: int, rate: float, seed: int = 0,
               process: str = "poisson", vocab: int = 251,
               classes: dict[str, SLAClass] = SLA_CLASSES,
               mix: dict = _MIX, burst_on: int = 8, burst_off: int = 8,
               burst_idle_rate: float = 0.2,
               diurnal_period: int = 48) -> list[OpenRequest]:
    """Generate one open-loop trace: arrivals per the chosen process, each
    request given an SLA class, heavy-tailed prompt/decode lengths, and
    its submit step.  ``batch`` requests arrive as prefix-affine gangs of
    the mix's gang size (consecutive batch arrivals share a gang id), so
    the engine's park/splice preemption has a whole gang to park.
    Deterministic: same arguments, same trace."""
    assert process in ("poisson", "bursty", "diurnal"), process
    rng = np.random.default_rng(seed)
    if process == "poisson":
        counts = poisson_arrivals(rate, steps, rng)
    elif process == "bursty":
        counts = bursty_arrivals(rate * (burst_on + burst_off) / burst_on,
                                 burst_idle_rate, burst_on, burst_off,
                                 steps, rng)
    else:
        counts = diurnal_arrivals(rate, rate * 0.8, diurnal_period,
                                  steps, rng)
    names = [n for n in mix if n in classes]
    shares = np.array([mix[n][0] for n in names], dtype=float)
    shares = shares / shares.sum()
    gang_seq: dict[str, tuple[int, int]] = {}     # class -> (gang no, fill)
    trace: list[OpenRequest] = []
    for step, n in enumerate(counts):
        for _ in range(n):
            name = names[int(rng.choice(len(names), p=shares))]
            _, plen_p, dlen_p, gang_size = mix[name]
            gang = None
            if gang_size > 1:
                no, fill = gang_seq.get(name, (0, 0))
                gang = f"{name[0]}g{no}"
                fill += 1
                gang_seq[name] = (no + 1, 0) if fill >= gang_size \
                    else (no, fill)
            trace.append(OpenRequest(
                step, name, rng.integers(1, vocab, _length(rng, *plen_p)),
                _length(rng, *dlen_p), gang))
    return trace


def make_agentic_trace(*, steps: int, rate: float, seed: int = 0,
                       vocab: int = 251, max_turns: int = 4,
                       turn_len: tuple = (1.7, 0.5, 2, 12),
                       think: tuple = (1.6, 0.8, 2, 24),
                       prompt_len: tuple = (2.0, 0.5, 4, 24),
                       gang_share: float = 0.35, gang_size: int = 4,
                       sla: str = "standard",
                       gang_sla: str = "batch") -> list[OpenRequest]:
    """Generate an agentic/tool-calling trace: chat *sessions* that decode
    a turn, hit a tool call, think for a heavy-tailed gap (clipped
    lognormal — most tool round-trips are short, a fat tail is not), then
    decode the next turn, for 1..``max_turns`` turns per session.  Each
    session is one request whose ``tool_calls`` carries the per-session
    turn chain ``((at_tokens, think_steps), ...)``; the engine sleeps the
    request at each marker and wakes it after the gap.

    A ``gang_share`` fraction of sessions arrive as prefix-affine gangs
    (one shared prompt, one shared tool-call schedule), so the whole gang
    sleeps and wakes together — the multi-agent shape where parked KV is
    the steady-state resource.  Deterministic: same arguments, same trace.
    """
    assert 0.0 <= gang_share <= 1.0 and gang_size >= 1, (gang_share,
                                                         gang_size)
    rng = np.random.default_rng(seed)
    counts = poisson_arrivals(rate, steps, rng)
    trace: list[OpenRequest] = []
    gno = 0
    for step, n in enumerate(counts):
        for _ in range(n):
            turns = int(rng.integers(1, max_turns + 1))
            lens = [_length(rng, *turn_len) for _ in range(turns)]
            calls, at = [], 0
            for length in lens[:-1]:
                at += length
                calls.append((at, _length(rng, *think)))
            prompt = rng.integers(1, vocab, _length(rng, *prompt_len))
            if gang_size > 1 and rng.random() < gang_share:
                gang = f"ag{gno}"
                gno += 1
                for _ in range(gang_size):
                    trace.append(OpenRequest(step, gang_sla, prompt,
                                             sum(lens), gang, tuple(calls)))
            else:
                trace.append(OpenRequest(step, sla, prompt, sum(lens),
                                         None, tuple(calls)))
    return trace


# ---------------------------------------------------------------------------
# the open-loop driver + latency accounting helpers
# ---------------------------------------------------------------------------

def drive(engine, trace: list[OpenRequest], *, max_steps: int = 20000,
          prio_from_class: Optional[dict[str, SLAClass]] = None):
    """Open-loop drive: submit each request AT its arrival step (the
    engine never sees the future), step the engine, run to drain.

    Works on any engine: one built with ``sla_classes`` schedules by
    class (WDRR + demotion + preemption); one built without is the
    hold-the-slot FIFO baseline — requests still carry their class label
    so both runs are judged by the same SLOs.  Returns the engine."""
    pending = sorted(trace, key=lambda r: r.step)
    i = 0
    while i < len(pending) or not engine._drained():
        now = engine.steps
        while i < len(pending) and pending[i].step <= now:
            r = pending[i]
            i += 1
            kw = {}
            if prio_from_class is not None and r.sla in prio_from_class:
                kw["prio"] = prio_from_class[r.sla].prio
            engine.submit(r.prompt, r.new_tokens, sla=r.sla, gang=r.gang,
                          tool_calls=r.tool_calls, **kw)
        engine.step()
        if engine.steps > max_steps:
            raise RuntimeError(
                f"open-loop drive did not drain in {max_steps} steps "
                f"({len(engine.completed)} done, {i}/{len(pending)} "
                "submitted)")
    return engine


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation): the
    smallest value with at least ``q`` percent of the sample at or below
    it.  Empty samples read 0.0."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(math.ceil(q / 100.0 * len(s))) - 1))
    return float(s[k])


def goodput_under_sla(completed, classes: dict[str, SLAClass] = SLA_CLASSES
                      ) -> tuple[int, int]:
    """``(good, total)`` over completed requests: a request is *good* when
    it completed AND its TTFT met its class's SLO (classes with no
    ``ttft_slo``, and unclassed requests, are good on completion).  Judged
    on the submitted class (``Request.sla``) — demotion changes how a
    long-runner is *scheduled*, never the contract it is measured by."""
    good = 0
    for r in completed:
        cls = classes.get(r.sla) if r.sla is not None else None
        if cls is None or cls.ttft_slo is None:
            good += 1
        elif (r.first_token_step is not None
              and r.first_token_step - r.submit_step <= cls.ttft_slo):
            good += 1
    return good, len(completed)
