"""Explicit expert-parallel MoE dispatch: shard_map + lax.all_to_all.

The §Perf hillclimb established that GSPMD cannot derive an efficient
program for cross-device expert dispatch from sharding annotations alone
(it all-gathers the expert buffers; EXPERIMENTS §Perf cell 2, iters 2/4/5).
This module is the explicit-collective answer — the DeepSpeed-MoE pattern
on jax-native primitives:

    per device:  route local tokens → per-target-expert-shard buffers
    all_to_all:  exchange buffers over the expert axis  (tokens → owners)
    local:       dense expert FFN on owned experts
    all_to_all:  send results back
    per device:  weighted combine

Works under ``shard_map`` over an ``("expert",)`` (sub-)mesh axis, with the
batch sharded over the remaining axes by GSPMD as usual.  Capacity is per
(source device × target device) so the exchanged buffers are statically
shaped, as ``lax.all_to_all`` requires.

This is a validated prototype wired for e.g. grok (8 experts over an
8-wide axis); integrating it behind ``moe_ffn`` for the full train step is
the documented next step, with the bubble planner already emitting the
expert placement it consumes.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_ep_ffn(mesh: Mesh, axis: str, n_experts: int, top_k: int,
                ffn_apply: Callable, cap_per_pair: int):
    """Build an expert-parallel FFN: (params_local, x_local) -> y_local.

    ``ffn_apply(wi, wg, wo, buf)``: dense per-expert FFN on (E_loc, C, D).
    ``cap_per_pair``: token capacity per (src shard, dst shard, local
    expert) — static all_to_all shape.
    """
    n_shards = mesh.shape[axis]
    assert n_experts % n_shards == 0
    e_loc = n_experts // n_shards

    def ep_ffn(wi, wg, wo, x, gate_idx, gate_vals):
        """Per-shard body (runs under shard_map).

        wi/wg/wo: (E_loc, ...) local expert weights.
        x: (T, D) local tokens; gate_idx/vals: (T, K) global expert ids.
        """
        T, D = x.shape
        K = gate_idx.shape[1]
        TK = T * K
        C = cap_per_pair

        flat_e = gate_idx.reshape(TK)                   # global expert id
        dst = flat_e // e_loc                           # target shard
        le = flat_e % e_loc                             # local expert there
        # rank within (dst, le) group, gather-only:
        key = dst * e_loc + le
        order = jnp.argsort(key)
        key_sorted = key[order]
        starts = jnp.searchsorted(key_sorted, jnp.arange(n_shards * e_loc),
                                  side="left")
        ends = jnp.searchsorted(key_sorted, jnp.arange(n_shards * e_loc),
                                side="right")
        idx = starts[:, None] + jnp.arange(C)[None]     # (S*E_loc, C)
        valid = idx < ends[:, None]
        idx = jnp.minimum(idx, TK - 1)
        src_assign = jnp.take_along_axis(
            jnp.broadcast_to(order[None], (n_shards * e_loc, TK)), idx,
            axis=1)                                     # assignment index
        src_tok = src_assign // K
        sbuf = x[src_tok.reshape(-1)].reshape(n_shards, e_loc * C, D)
        sbuf = sbuf * valid.reshape(n_shards, e_loc * C, 1).astype(x.dtype)

        # exchange: dim0 = shard axis
        rbuf = jax.lax.all_to_all(sbuf, axis, 0, 0, tiled=False)
        # rbuf: (n_shards, e_loc*C, D) — tokens from every source shard
        rbuf = rbuf.reshape(n_shards, e_loc, C, D).transpose(1, 0, 2, 3) \
            .reshape(e_loc, n_shards * C, D)

        out = ffn_apply(wi, wg, wo, rbuf)               # (e_loc, S*C, D)

        # send back
        out = out.reshape(e_loc, n_shards, C, D).transpose(1, 0, 2, 3) \
            .reshape(n_shards, e_loc * C, D)
        back = jax.lax.all_to_all(out, axis, 0, 0, tiled=False)
        # back[s, e*C + c] = result for the token we packed at (s, e, c)

        # combine: invert the packing (gather-only)
        inv = jnp.argsort(order)
        pos_sorted = jnp.arange(TK) - jnp.take(starts, key_sorted)
        pos = jnp.take(pos_sorted, inv)                 # (TK,)
        kept = pos < C
        rows = jnp.where(kept, dst * (e_loc * C) + le * C + pos, 0)
        flat = back.reshape(n_shards * e_loc * C, D)
        got = flat[rows]                                # (TK, D)
        w = (gate_vals.reshape(TK) * kept).astype(x.dtype)
        y = (got * w[:, None]).reshape(T, K, D).sum(axis=1)
        return y

    # shard_map wrapper: tokens replicated per expert-shard? No — tokens are
    # sharded over the OTHER axes by the caller; over `axis` each shard
    # holds a distinct slice of the batch (standard EP: batch × expert grid)
    pspec_w = P(axis)            # expert-sharded weights (E dim leading)
    pspec_x = P(axis)            # batch slice per expert shard
    f = shard_map(ep_ffn, mesh=mesh,
                  in_specs=(pspec_w, pspec_w, pspec_w, pspec_x, pspec_x,
                            pspec_x),
                  out_specs=pspec_x,
                  check_rep=False)
    return f
