from . import hlo, sharding

__all__ = ["hlo", "sharding"]
