"""Compiled-HLO analysis: collective bytes + roofline terms.

``collective_bytes`` parses the post-SPMD optimized HLO text and sums the
per-shard result sizes of every collective op.  Shapes in post-partitioning
HLO are already per-device, so the sums are per-chip traffic.  All-reduce is
counted twice (reduce-scatter + all-gather phases of a ring); the (n-1)/n
ring factor is folded to 1 — a ≤7% overstatement on 16-wide rings, noted in
EXPERIMENTS.md.

``roofline`` turns cost_analysis + collective bytes into the three terms
(seconds) against the v5e-class hardware constants from the brief.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# hardware constants (per chip) — TPU v5e-class, from the brief
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~per-chip injection)
DCN_BW = 6.25e9              # bytes/s per chip across pods (50 Gbit/s)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>.+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]\w*?)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    by_op: dict = field(default_factory=dict)       # op -> (count, bytes)

    @property
    def total_bytes(self) -> int:
        return sum(b for _, b in self.by_op.values())

    @property
    def weighted_bytes(self) -> float:
        """All-reduce counted 2x (RS+AG phases)."""
        out = 0.0
        for op, (_, b) in self.by_op.items():
            out += b * (2.0 if op == "all-reduce" else 1.0)
        return out

    def summary(self) -> dict:
        return {op: {"count": c, "bytes": b}
                for op, (c, b) in sorted(self.by_op.items())}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:          # async pair: count only the start
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("out"))
        c, b = stats.by_op.get(op, (0, 0))
        stats.by_op[op] = (c + 1, b + nbytes)
    return stats


def cross_pod_bytes(hlo_text: str, pod_pairs: set[tuple[int, int]]) -> int:
    """Best-effort: bytes of collectives whose replica groups span pods.

    ``pod_pairs`` unused in the regex fallback; we approximate by checking
    whether any replica group in the op line contains device ids from more
    than one pod (ids >= 256 and < 256 together)."""
    total = 0
    group_re = re.compile(r"replica_groups=\{([^}]*)\}")
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        g = group_re.search(line)
        if not g:
            continue
        spans = False
        for grp in g.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and (min(ids) < 256 <= max(ids)):
                spans = True
                break
        if spans:
            total += _shape_bytes(m.group("out"))
    return total


@dataclass
class Roofline:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective bytes (weighted)
    dcn_bytes: float = 0.0       # subset crossing pods
    model_flops: float = 0.0     # analytic 6*N*D (global)
    chips: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        ici = (self.coll_bytes - self.dcn_bytes) / ICI_BW
        dcn = self.dcn_bytes / DCN_BW
        return ici + dcn

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_step(self) -> float:
        """Roofline step time = max of the three (full overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs)."""
        if self.flops <= 0:
            return 0.0
        return self.model_flops / (self.chips * self.flops)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        if self.t_step <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_step) / PEAK_FLOPS

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "dcn_bytes_per_chip": self.dcn_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "mfu_at_roofline": self.mfu,
        }
