"""Plan → PartitionSpec trees: the bubble scheduler's output made executable.

``param_specs``   — every parameter, from its logical-dim annotation.
``opt_specs``     — optimizer state: parameter sharding + ZeRO-1 (the first
                    still-unsharded heavy dim additionally sharded over
                    ``data``), the analogue of the paper's "distribute the
                    memory where the bubble lives".
``batch_specs``   — input batch (batch dim over the plan's batch axes).
``state_specs``   — decode caches: batch over data axes, kv-time/heads over
                    the model axis as the plan dictates.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.planner import Plan
from repro.models import api
from repro.models.config import ModelConfig

# dims eligible for the extra ZeRO-1 ``data`` sharding of optimizer state,
# in preference order (first match on each tensor wins)
_ZERO_DIMS = ("d_model", "d_ff", "lru", "heads_flat", "vocab", "experts")


def _spec_from_dims(dims: tuple, plan: Plan,
                    mesh_axes: set[str]) -> P:
    used: set[str] = set()
    entries = []
    for d in dims:
        ax = plan.axes_of(d)
        if ax:
            ax = tuple(a for a in ax if a in mesh_axes and a not in used)
        if ax:
            entries.append(ax if len(ax) > 1 else ax[0])
            used.update(ax)
        else:
            entries.append(None)
    return P(*entries)


def param_specs(cfg: ModelConfig, plan: Plan, mesh: Mesh,
                extra_storage: tuple = ()):
    """``extra_storage``: mesh axes added FSDP-style to the first eligible
    unsharded heavy dim of each parameter (storage sharding; XLA inserts
    the per-layer all-gather)."""
    mesh_axes = set(mesh.axis_names)
    dims_tree = api.dims(cfg)

    def one(dims):
        spec = _spec_from_dims(dims, plan, mesh_axes)
        for ax in extra_storage:
            if ax in mesh_axes:
                spec = _zero_spec(dims, spec, mesh_axes, axis=ax)
        return spec

    return jax.tree.map(one, dims_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _zero_spec(dims: tuple, base: P, mesh_axes: set[str],
               axis: str = "data") -> P:
    """Add ZeRO/FSDP ``axis`` sharding to the first eligible unsharded dim."""
    if axis not in mesh_axes:
        return base
    used = set()
    for e in base:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    if axis in used:
        return base
    entries = list(base)
    for pref in _ZERO_DIMS:
        for i, d in enumerate(dims):
            if d == pref and entries[i] is None:
                entries[i] = axis
                return P(*entries)
    return base


def opt_specs(cfg: ModelConfig, plan: Plan, mesh: Mesh):
    """AdamWState sharding: step replicated; master/m/v = param + ZeRO."""
    mesh_axes = set(mesh.axis_names)
    dims_tree = api.dims(cfg)
    pspecs = param_specs(cfg, plan, mesh)
    zero = jax.tree.map(
        lambda dims, base: _zero_spec(dims, base, mesh_axes),
        dims_tree, pspecs, is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), master=zero, m=zero, v=zero)


def _batch_axes(plan: Plan) -> Any:
    ax = plan.axes_of("batch")
    if not ax:
        return None
    return ax if len(ax) > 1 else ax[0]


def batch_specs(cfg: ModelConfig, plan: Plan, batch_tree) -> Any:
    """Shard the leading (batch) dim of every input leaf."""
    b = _batch_axes(plan)

    def spec(leaf):
        nd = len(leaf.shape)
        return P(*((b,) + (None,) * (nd - 1)))

    return jax.tree.map(spec, batch_tree)


def state_specs(cfg: ModelConfig, plan: Plan, state_tree) -> Any:
    """Decode-state sharding.

    Stacked state leaves have a leading repeats axis.  Layout per kind:
      KVCache k/v  (R, B, C, K, hd) — batch over data; the cache *time* axis
        over the model axis (flash-decode partitioning) for MHA/GQA, since
        kv heads rarely fill the model axis.
      pos          (R, B)           — batch only.
      LRU/RWKV     (R, B, ...)      — batch over data, widest feature dim
        over model when divisible.
    """
    b = _batch_axes(plan)
    b_set = set(b) if isinstance(b, tuple) else ({b} if b else set())
    model_ax = None
    for cand in ("heads", "lru", "heads_flat", "d_ff"):
        ax = plan.axes_of(cand)
        if ax and ax[-1] not in b_set:
            model_ax = ax[-1]
            break

    def spec(leaf):
        shp = leaf.shape
        nd = len(shp)
        if nd >= 4 and model_ax is not None:
            # (R, B, C, K, hd) kv cache or (R, B, H, hd, hd) wkv state:
            # shard the largest non-batch axis over model if divisible
            axes: list = [None, b] + [None] * (nd - 2)
            sizes = [(i, shp[i]) for i in range(2, nd)]
            sizes.sort(key=lambda t: -t[1])
            msize = _axis_size(model_ax)
            for i, s in sizes:
                if msize and s % msize == 0:
                    axes[i] = model_ax
                    break
            return P(*axes)
        if nd >= 2:
            return P(None, b, *([None] * (nd - 2)))
        return P(*([None] * nd))

    def _axis_size(name):
        return _MESH_SIZES.get(name)

    return jax.tree.map(spec, state_tree)


# set by shardings() so state_specs can check divisibility
_MESH_SIZES: dict[str, int] = {}


def sharded_bytes(specs_tree, shardings_tree) -> int:
    """Exact per-chip bytes of a ShapeDtypeStruct tree under shardings.

    The CPU backend's ``memory_analysis`` reports zeros, so argument sizes
    for the dry-run are computed analytically (they are exact: per-chip
    shard bytes = global bytes / prod(sizes of axes used by the spec))."""
    total = 0
    for sds, sh in zip(jax.tree.leaves(specs_tree),
                       jax.tree.leaves(shardings_tree,
                                       is_leaf=lambda x: isinstance(
                                           x, NamedSharding))):
        n = 1
        for d in sds.shape:
            n *= d
        n *= jnp.dtype(sds.dtype).itemsize
        div = 1
        sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
        for entry in sh.spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                div *= sizes[ax]
        total += -(-n // div)        # ceil
    return total


def shardings(cfg: ModelConfig, plan: Plan, mesh: Mesh, shape: str,
              extra_storage: tuple = ()):
    """One-stop bundle for a workload cell: NamedShardings for every
    argument of the step function."""
    global _MESH_SIZES
    _MESH_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))

    def named(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)

    kind = api.SHAPES[shape]["kind"]
    out: dict[str, Any] = {"params": named(
        param_specs(cfg, plan, mesh, extra_storage=extra_storage))}
    specs = api.input_specs(cfg, shape)
    if kind == "train":
        out["opt"] = named(opt_specs(cfg, plan, mesh))
        out["batch"] = named(batch_specs(cfg, plan, specs))
    elif kind == "prefill":
        out["batch"] = named(batch_specs(cfg, plan, specs))
    else:  # decode
        tok = {"token": specs["token"]}
        out["token"] = named(batch_specs(cfg, plan, tok))["token"]
        out["states"] = named(state_specs(cfg, plan, specs["states"]))
        if "enc" in specs:
            out["enc"] = named(batch_specs(cfg, plan,
                                           {"enc": specs["enc"]}))["enc"]
    return out
