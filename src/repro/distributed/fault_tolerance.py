"""Fault tolerance: elastic re-meshing, checkpoint-restart, stragglers.

The bubble model makes elasticity a *re-plan*: the application's bubble tree
is machine-independent, so when the fleet shrinks (a pod or a host goes
away) we rebuild the mesh from survivors, run the planner against the new
axis hierarchy, and restore the latest checkpoint with the new shardings —
the exact analogue of bubble regeneration after a processor disappears
("idle processors move bubbles down on their side and have them re-burst,
getting a new distribution suited to the new workload while keeping
affinity intact", §3.3.3).

Straggler mitigation is bubble regeneration at step granularity: per-host
step times feed an EWMA detector; a persistent straggler's work-bubbles are
regenerated (pulled back to the parent queue) and stolen by healthy hosts.
The detector + policy are here; the serving engine and the train driver
call into them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.planner import MeshAxis, Plan, plan_bubbles
from repro.core.bubble import Bubble


@dataclasses.dataclass
class FleetSpec:
    """Declarative fleet: which (pod, data, model) coordinates are alive."""
    pods: int
    data: int
    model: int
    dead_pods: frozenset = frozenset()
    dead_hosts: frozenset = frozenset()     # (pod, data-slice) pairs

    def _survivor_grid(self) -> tuple[int, int]:
        """``(kept_pods, kept_data)`` of the largest fully-alive rectangle.

        A rectangular mesh keeps a pod only with *every* kept data column
        alive in it, so a kept pod's dead columns are excluded fleet-wide —
        but a pod with dead hosts can instead be dropped entirely, keeping
        its healthy twins' columns for everyone else.  We search exactly:
        only pods that contain dead hosts face that keep-or-drop choice, and
        real kill sets touch few pods, so enumerating their subsets is tiny.
        (Beyond 16 dirty pods we fall back to sorted prefixes — keep the
        pods with the fewest dead columns first — which covers the monotone
        shapes real failures take.)
        """
        pods_alive = [p for p in range(self.pods) if p not in self.dead_pods]
        dead_by_pod: dict[int, set] = {}
        for p, d in self.dead_hosts:
            if p in self.dead_pods:
                continue                    # its whole pod is already gone
            dead_by_pod.setdefault(p, set()).add(d)
        clean = sum(1 for p in pods_alive if p not in dead_by_pod)
        dirty = sorted((p for p in pods_alive if p in dead_by_pod),
                       key=lambda p: (len(dead_by_pod[p]), p))
        if len(dirty) <= 16:
            choices = range(1 << len(dirty))
            subset = lambda m: [dirty[i] for i in range(len(dirty))
                                if m >> i & 1]
        else:
            choices = range(len(dirty) + 1)
            subset = lambda m: dirty[:m]
        best = None
        for m in choices:
            keep = subset(m)
            cols_dead = set().union(*(dead_by_pod[p] for p in keep)) \
                if keep else set()
            rows = clean + len(keep)
            cols = self.data - len(cols_dead)
            if rows <= 0 or cols <= 0:
                continue
            # deterministic tie-break: prefer more pods (preserves the
            # pod axis, the shape the planner laid the job out for)
            key = (rows * cols, rows)
            if best is None or key > best[0]:
                best = (key, rows, cols)
        if best is None:
            raise RuntimeError("fleet exhausted")
        return best[1], best[2]

    def alive_shape(self) -> tuple[int, ...]:
        pods, data = self._survivor_grid()
        if pods > 1:
            return (pods, data, self.model)
        return (data, self.model)

    def alive_axes(self) -> tuple[str, ...]:
        return (("pod", "data", "model") if self._survivor_grid()[0] > 1
                else ("data", "model"))


def rebuild_mesh(spec: FleetSpec, devices: Optional[Sequence] = None):
    """Mesh over surviving devices (largest rectangular slice)."""
    shape = spec.alive_shape()
    axes = spec.alive_axes()
    n = int(np.prod(shape))
    devices = (devices or jax.devices())
    if len(devices) < n:
        raise RuntimeError(f"not enough devices: need {n}")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def replan(tree: Bubble, mesh) -> Plan:
    axes = [MeshAxis(n, s) for n, s in
            zip(mesh.axis_names, mesh.devices.shape)]
    return plan_bubbles(tree, axes)


def elastic_restart(tree: Bubble, spec: FleetSpec, ckpt_dir, like, *,
                    make_shardings: Callable, devices=None):
    """Full recovery path: survivors → mesh → plan → shardings → restore.

    ``make_shardings(plan, mesh) -> pytree of NamedSharding`` matching
    ``like``.  Returns (mesh, plan, restored_tree, step)."""
    from repro import checkpoint as ckpt
    mesh = rebuild_mesh(spec, devices)
    plan = replan(tree, mesh)
    sh = make_shardings(plan, mesh)
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        raise RuntimeError(f"no checkpoint under {ckpt_dir}")
    restored, manifest = ckpt.restore(ckpt_dir, step, like, shardings=sh)
    return mesh, plan, restored, step


# ---------------------------------------------------------------------------
# straggler detection (EWMA of per-host step times)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 1.5          # x median EWMA
    alpha: float = 0.3
    min_samples: int = 3
    ewma: dict = dataclasses.field(default_factory=dict)
    count: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: str, step_time: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_time if prev is None
                           else self.alpha * step_time + (1 - self.alpha) * prev)
        self.count[host] = self.count.get(host, 0) + 1

    def stragglers(self) -> list[str]:
        ready = {h: t for h, t in self.ewma.items()
                 if self.count[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [h for h, t in ready.items() if t > self.threshold * med]


def regenerate_straggler_bubbles(sched, straggler_cpus: Sequence[int]):
    """Pull every bubble homed on a straggler's queues back to the parent
    level so healthy cpus pick it up (paper §3.3.3 regeneration).  Returns
    the number of bubbles moved.

    Each task moves exactly **one** level up and is counted once: the move
    plan is snapshotted for every queue before anything moves, so a task
    pushed onto its parent is never re-moved by the next (queue, parent)
    pair — cascading everything to the global list would destroy exactly
    the affinity §3.3.3 regeneration is meant to keep.  Queues shared by
    several stragglers' covering chains are drained once.
    """
    plan = []                           # (queue, parent, tasks-at-snapshot)
    seen: set[int] = set()
    for cpu in straggler_cpus:
        chain = sched.queues.covering(cpu)      # local → global
        for q, parent in zip(chain[:-1], chain[1:]):
            if id(q) in seen:
                continue
            seen.add(id(q))
            plan.append((q, parent, list(q.tasks)))
    moved = 0
    for q, parent, tasks in plan:
        for t in tasks:
            if q.remove(t):
                parent.push(t)
                moved += 1
    return moved
