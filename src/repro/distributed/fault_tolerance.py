"""Fault tolerance: elastic re-meshing, checkpoint-restart, stragglers.

The bubble model makes elasticity a *re-plan*: the application's bubble tree
is machine-independent, so when the fleet shrinks (a pod or a host goes
away) we rebuild the mesh from survivors, run the planner against the new
axis hierarchy, and restore the latest checkpoint with the new shardings —
the exact analogue of bubble regeneration after a processor disappears
("idle processors move bubbles down on their side and have them re-burst,
getting a new distribution suited to the new workload while keeping
affinity intact", §3.3.3).

Straggler mitigation is bubble regeneration at step granularity: per-host
step times feed an EWMA detector; a persistent straggler's work-bubbles are
regenerated (pulled back to the parent queue) and stolen by healthy hosts.
The detector + policy are here; the serving engine and the train driver
call into them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.planner import MeshAxis, Plan, plan_bubbles
from repro.core.bubble import Bubble


@dataclasses.dataclass
class FleetSpec:
    """Declarative fleet: which (pod, data, model) coordinates are alive."""
    pods: int
    data: int
    model: int
    dead_pods: frozenset = frozenset()
    dead_hosts: frozenset = frozenset()     # (pod, data-slice) pairs

    def alive_shape(self) -> tuple[int, ...]:
        pods = self.pods - len(self.dead_pods)
        data = self.data - len({d for _, d in self.dead_hosts})
        if pods <= 0 or data <= 0:
            raise RuntimeError("fleet exhausted")
        if pods > 1:
            return (pods, data, self.model)
        return (data, self.model)

    def alive_axes(self) -> tuple[str, ...]:
        return (("pod", "data", "model") if self.pods - len(self.dead_pods) > 1
                else ("data", "model"))


def rebuild_mesh(spec: FleetSpec, devices: Optional[Sequence] = None):
    """Mesh over surviving devices (largest rectangular slice)."""
    shape = spec.alive_shape()
    axes = spec.alive_axes()
    n = int(np.prod(shape))
    devices = (devices or jax.devices())
    if len(devices) < n:
        raise RuntimeError(f"not enough devices: need {n}")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def replan(tree: Bubble, mesh) -> Plan:
    axes = [MeshAxis(n, s) for n, s in
            zip(mesh.axis_names, mesh.devices.shape)]
    return plan_bubbles(tree, axes)


def elastic_restart(tree: Bubble, spec: FleetSpec, ckpt_dir, like, *,
                    make_shardings: Callable, devices=None):
    """Full recovery path: survivors → mesh → plan → shardings → restore.

    ``make_shardings(plan, mesh) -> pytree of NamedSharding`` matching
    ``like``.  Returns (mesh, plan, restored_tree, step)."""
    from repro import checkpoint as ckpt
    mesh = rebuild_mesh(spec, devices)
    plan = replan(tree, mesh)
    sh = make_shardings(plan, mesh)
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        raise RuntimeError(f"no checkpoint under {ckpt_dir}")
    restored, manifest = ckpt.restore(ckpt_dir, step, like, shardings=sh)
    return mesh, plan, restored, step


# ---------------------------------------------------------------------------
# straggler detection (EWMA of per-host step times)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerDetector:
    threshold: float = 1.5          # x median EWMA
    alpha: float = 0.3
    min_samples: int = 3
    ewma: dict = dataclasses.field(default_factory=dict)
    count: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: str, step_time: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_time if prev is None
                           else self.alpha * step_time + (1 - self.alpha) * prev)
        self.count[host] = self.count.get(host, 0) + 1

    def stragglers(self) -> list[str]:
        ready = {h: t for h, t in self.ewma.items()
                 if self.count[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [h for h, t in ready.items() if t > self.threshold * med]


def regenerate_straggler_bubbles(sched, straggler_cpus: Sequence[int]):
    """Pull every bubble homed on a straggler's queues back to the parent
    level so healthy cpus pick it up (paper §3.3.3 regeneration).  Returns
    the number of bubbles moved."""
    moved = 0
    for cpu in straggler_cpus:
        chain = sched.queues.covering(cpu)      # local → global
        for q, parent in zip(chain[:-1], chain[1:]):
            for t in list(q.tasks):
                q.remove(t)
                parent.push(t)
                moved += 1
    return moved
