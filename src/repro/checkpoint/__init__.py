from .store import latest_step, manifest_extra, restore, save
from .kv_store import KVSnapshot, KVStore

__all__ = ["save", "restore", "latest_step", "manifest_extra",
           "KVStore", "KVSnapshot"]
