from .store import latest_step, manifest_extra, restore, save

__all__ = ["save", "restore", "latest_step", "manifest_extra"]
