"""Sharded checkpoint store: save/restore with restart manifest.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json      — tree structure, shapes, dtypes, step metadata
        <leaf-path>.npy    — one file per tensor leaf

On a fleet each host writes only the shards it owns (addressable-shards
loop); here the single process writes everything, but the manifest records
the intended sharding so restore can re-lay tensors onto a *different* mesh
— that is the elastic-restart path (fault tolerance: lose a pod, restart on
the surviving mesh from the same checkpoint).

Writes are atomic (tmp dir + rename) so a mid-write crash never corrupts
the latest complete checkpoint; ``latest_step`` scans for the newest
complete manifest.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# numpy cannot serialise bfloat16 natively; stored as a uint16 view with the
# true dtype recorded in the manifest
_BF16 = "bfloat16"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(_key_str(k) for k in kp)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save(dirpath: str | Path, step: int, tree, *,
         extra: Optional[dict] = None) -> Path:
    """Atomic checkpoint write.  Returns the final directory."""
    dirpath = Path(dirpath)
    final = dirpath / f"step_{step:08d}"
    tmp = dirpath / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        true_dtype = str(jnp.asarray(leaf).dtype) if hasattr(leaf, "dtype") \
            else str(arr.dtype)
        if true_dtype == _BF16:
            arr = arr.view(np.uint16)
        fn = name.replace("/", "__") + ".npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": true_dtype,
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(dirpath: str | Path) -> Optional[int]:
    dirpath = Path(dirpath)
    if not dirpath.exists():
        return None
    best = None
    for d in dirpath.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            s = int(d.name.split("_")[1])
            best = s if best is None else max(best, s)
    return best


def restore(dirpath: str | Path, step: int, like, *,
            shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` re-lays tensors onto the current
    mesh (which may differ from the writer's — elastic restart)."""
    final = Path(dirpath) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())

    names = [n for n, _ in _leaf_paths(like)]
    leaves = []
    for name in names:
        info = manifest["leaves"][name]
        arr = np.load(final / info["file"])
        if info["dtype"] == _BF16:
            arr = jnp.asarray(arr.view(np.uint16)).view(jnp.bfloat16)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(lambda a: jax.numpy.asarray(a), tree)
    return tree, manifest


def manifest_extra(dirpath: str | Path, step: int) -> dict:
    final = Path(dirpath) / f"step_{step:08d}"
    return json.loads((final / "manifest.json").read_text())["extra"]
