"""KV checkpoint store: cadenced snapshots of the serving engine's live KV.

Where :mod:`repro.checkpoint.store` checkpoints *model parameters* for the
training restart path, this store checkpoints *decode continuations*: for
every request resident in a slot, the backend state needed to resume its
stream (the KV pages / recurrent state), the last emitted token, and how
many tokens had been emitted at snapshot time.  When a host dies
mid-decode, its residents' HBM pages vanish — the engine then restores each
orphan either from the newest snapshot here (pay the per-byte transfer toll
plus a short replay of the tokens emitted since the snapshot) or by
re-prefilling from scratch, whichever the cost model quotes cheaper.

The on-disk discipline mirrors ``store.py`` exactly — one directory per
snapshot step, written into a ``.tmp_step_*`` dir and ``os.replace``'d into
place, with a ``manifest.json`` recording every entry — so a crash mid-write
never corrupts the newest complete snapshot and ``latest_step`` semantics
are shared.  Unlike ``store.py`` it restores without a ``like`` tree: each
entry's state is an arbitrary nested tuple/list/dict pytree of arrays, and
the manifest records the structure.  The module is numpy-only so the stub
engine (and tier-1 CI) never pays a jax import for elasticity tests.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import numpy as np

_BF16 = "bfloat16"


@dataclasses.dataclass
class KVSnapshot:
    """One restorable continuation: resume ``rid`` by feeding ``tok`` (its
    ``emitted``-th output token) to a backend holding ``state``."""
    rid: int
    state: Any
    tok: int
    emitted: int


def _encode(node, files: list, prefix: str):
    """Recursively encode a state pytree: arrays become npy files, structure
    becomes a JSON spec.  Returns the spec."""
    if isinstance(node, dict):
        keys = sorted(node)
        return {"t": "dict", "keys": keys,
                "items": [_encode(node[k], files, f"{prefix}_{i}")
                          for i, k in enumerate(keys)]}
    if isinstance(node, (list, tuple)):
        return {"t": "list" if isinstance(node, list) else "tuple",
                "items": [_encode(v, files, f"{prefix}_{i}")
                          for i, v in enumerate(node)]}
    arr = np.asarray(node)
    dtype = str(arr.dtype)
    if dtype == _BF16:                   # ml_dtypes leaf via a jax backend
        arr = arr.view(np.uint16)
    fn = f"{prefix}.npy"
    files.append((fn, arr))
    return {"t": "arr", "file": fn, "dtype": dtype}


def _decode(spec, dirpath: Path):
    if spec["t"] == "dict":
        return {k: _decode(s, dirpath)
                for k, s in zip(spec["keys"], spec["items"])}
    if spec["t"] in ("list", "tuple"):
        items = [_decode(s, dirpath) for s in spec["items"]]
        return items if spec["t"] == "list" else tuple(items)
    arr = np.load(dirpath / spec["file"])
    if spec["dtype"] == _BF16:
        try:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        except ImportError:              # numpy-only env: hand back uint16
            pass                         # bits; the jax backend re-views
    return arr


def latest_step(dirpath: str | Path) -> Optional[int]:
    """Newest complete snapshot step, ignoring in-flight ``.tmp_step_*``
    dirs and directories whose manifest never landed."""
    dirpath = Path(dirpath)
    if not dirpath.exists():
        return None
    best = None
    for d in dirpath.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            s = int(d.name.split("_")[1])
            best = s if best is None else max(best, s)
    return best


class KVStore:
    """Cadenced snapshot writer + restorer for decode continuations.

    ``maybe_snapshot(step, entries)`` is called every engine step; it
    writes at most once per ``cadence`` steps.  ``entries`` maps
    ``rid -> (state, tok, emitted)``.  Restore gives back
    ``{rid: KVSnapshot}`` from the newest complete snapshot.
    """

    def __init__(self, dirpath: str | Path, cadence: int = 8):
        assert cadence >= 1
        self.dirpath = Path(dirpath)
        self.cadence = cadence
        self._last: Optional[int] = None

    def due(self, step: int) -> bool:
        """Whether the cadence calls for a snapshot at ``step`` — cheap,
        so callers can skip gathering entries on off-cadence steps."""
        return self._last is None or step - self._last >= self.cadence

    def maybe_snapshot(self, step: int, entries: dict) -> bool:
        if not self.due(step):
            return False
        self.snapshot(step, entries)
        return True

    def snapshot(self, step: int, entries: dict) -> Path:
        """Unconditional atomic snapshot write (tmp dir + rename)."""
        final = self.dirpath / f"step_{step:08d}"
        tmp = self.dirpath / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        files: list[tuple[str, np.ndarray]] = []
        manifest = {"step": step, "entries": {}}
        for rid, (state, tok, emitted) in entries.items():
            spec = _encode(state, files, f"r{rid}")
            manifest["entries"][str(rid)] = {
                "tok": int(tok), "emitted": int(emitted), "spec": spec}
        for fn, arr in files:
            np.save(tmp / fn, arr)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._last = step
        return final

    def latest(self) -> Optional[int]:
        return latest_step(self.dirpath)

    def restore(self, step: Optional[int] = None) -> dict[int, KVSnapshot]:
        """``{rid: KVSnapshot}`` from ``step`` (default: newest complete).
        An empty dict when no snapshot exists — the caller then quotes only
        the re-prefill path."""
        if step is None:
            step = self.latest()
        if step is None:
            return {}
        final = self.dirpath / f"step_{step:08d}"
        manifest = json.loads((final / "manifest.json").read_text())
        out: dict[int, KVSnapshot] = {}
        for rid_s, info in manifest["entries"].items():
            rid = int(rid_s)
            out[rid] = KVSnapshot(rid=rid,
                                  state=_decode(info["spec"], final),
                                  tok=info["tok"], emitted=info["emitted"])
        return out
