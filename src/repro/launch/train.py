"""Training driver: bubble-planned sharded train loop with fault tolerance.

Runs on any mesh (1x1 on this CPU container; 16x16 / 2x16x16 in
production — same code path).  Features:

* bubble-planner-derived shardings (``--strategy bubbles|simple|bound``)
* AdamW with fp32 master + bf16 moments, ZeRO-1 over ``data``
* block-granularity remat, donated buffers
* checkpoint/restart (atomic, manifest-based; ``--resume`` picks up the
  latest step, including onto a *different* mesh — elastic restart)
* straggler detector fed with per-step wall times
* optional int8 error-feedback gradient compression for the cross-pod hop

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 10 --batch 4 --seq 64
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCHS, get_config
from repro.core.planner import MeshAxis, plan_bubbles, plan_simple
from repro.data import DataConfig, PrefetchBuffer, ShardedTokenStream
from repro.distributed import sharding as shard_mod
from repro.distributed.fault_tolerance import StragglerDetector
from repro.launch.mesh import make_mesh, mesh_axes
from repro.models import api
from repro.optim import adamw


def build_train_step(cfg, acfg, use_compression: bool = False):
    loss_fn = api.make_loss_fn(cfg, remat=True)
    pdtype = cfg.pdtype

    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if use_compression:
            from repro.optim import compression
            # int8 quantise-dequantise on the gradient path (the cross-pod
            # all-reduce then moves int8 bytes; EF residual is carried in
            # the opt state extra slot in the full deployment)
            qs = jax.tree.map(lambda g: compression.quantize(g), grads,
                              is_leaf=lambda x: hasattr(x, "dtype"))
            grads = jax.tree.map(lambda t: compression.dequantize(*t), qs,
                                 is_leaf=lambda t: isinstance(t, tuple))
        new_params, new_opt = adamw.apply(grads, opt, acfg,
                                          param_dtype=pdtype)
        return loss, new_params, new_opt

    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--strategy", default="bubbles",
                    choices=["bubbles", "simple"])
    ap.add_argument("--mesh", default="1x1",
                    help="e.g. 1x1, 2x4, 2x16x16 (axes inferred)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(shape)]
    mesh = make_mesh(shape, axes)
    maxes = [MeshAxis(n, s) for n, s in mesh_axes(mesh)]

    # plan via the bubble scheduler (or the opportunist baseline)
    tree = api.bubble_tree(cfg, "train_4k")
    # patch the batch width to the actual run batch
    for d in tree.children[0].children:
        d.width = args.batch
    plan = (plan_bubbles(tree, maxes) if args.strategy == "bubbles"
            else plan_simple("batch", maxes))
    print(plan.pretty())

    with mesh:
        pspec_tree = shard_mod.param_specs(cfg, plan, mesh)
        p_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), pspec_tree)
        o_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            shard_mod.opt_specs(cfg, plan, mesh))

        key = jax.random.PRNGKey(args.seed)
        params = api.init(cfg, key)
        params = jax.tree.map(jax.device_put, params, p_sh)
        acfg = adamw.AdamWConfig(lr=args.lr)
        opt = adamw.init(params)

        start = 0
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                params, _ = ckpt.restore(args.ckpt_dir, latest, params,
                                         shardings=p_sh)
                opt, _ = ckpt.restore(Path(args.ckpt_dir) / "opt", latest,
                                      opt)
                start = latest
                print(f"resumed from step {latest}")

        data = ShardedTokenStream(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            seed=args.seed))
        it = PrefetchBuffer(data.shard(0, 0))

        step_fn = jax.jit(
            build_train_step(cfg, acfg, args.compress_grads),
            donate_argnums=(0, 1))
        detector = StragglerDetector()

        host = "host0"
        for step in range(start, args.steps):
            batch = next(it)
            t0 = time.time()
            loss, params, opt = step_fn(params, opt, batch)
            loss = float(loss)
            dt = time.time() - t0
            detector.observe(host, dt)
            print(f"step {step:5d} loss {loss:8.4f} {dt*1e3:7.1f}ms")
            assert np.isfinite(loss), "loss diverged"
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(args.ckpt_dir, step + 1, params,
                          extra={"arch": cfg.name, "loss": loss})
                ckpt.save(Path(args.ckpt_dir) / "opt", step + 1, opt)
        stragglers = detector.stragglers()
        if stragglers:
            print(f"stragglers detected: {stragglers}")
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
