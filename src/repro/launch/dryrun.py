import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  1. the model emits its bubble tree; the bubble planner derives the
     sharding plan against the mesh-axis hierarchy;
  2. the full step function (train_step = fwd+bwd+AdamW update; serve
     prefill; serve decode) is jit'd with in/out shardings from the plan
     and lowered against ShapeDtypeStruct inputs (no allocation);
  3. ``compiled.memory_analysis()`` proves the cell fits per-chip HBM;
     ``compiled.cost_analysis()`` + HLO collective parsing feed §Roofline.

Results are written incrementally to ``benchmarks/results/dryrun/`` as JSON
so reruns resume and EXPERIMENTS.md tables are reproducible.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # 512-chip
  PYTHONPATH=src python -m repro.launch.dryrun --strategy simple  # baseline
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core.planner import MeshAxis, plan_bubbles, plan_simple
from repro.distributed import hlo as hlo_mod
from repro.distributed import sharding as shard_mod
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models import api
from repro.optim import adamw

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


STRATEGIES = ("bubbles", "simple", "bound", "bubbles_sp", "fsdp_sp",
              "ep2d", "ep2d_sp", "bubbles_fsdp", "bubbles_fsdp_sp")


def strategy_parts(strategy):
    """(base plan name, sp?, extra_storage axes)."""
    sp = strategy.endswith("_sp")
    base = strategy[:-3] if sp else strategy
    storage = {"fsdp": ("model",),
               "bubbles_fsdp": ("data",)}.get(base, ())
    if base == "bubbles_fsdp":
        base = "bubbles"
    return base, sp, storage


def make_plan(cfg, shape, mesh, strategy="bubbles"):
    axes = [MeshAxis(n, s) for n, s in mesh_axes(mesh)]
    strategy = strategy_parts(strategy)[0]
    if strategy == "simple":
        return plan_simple("batch", axes)
    if strategy == "fsdp":
        # no TP: batch data-parallel, params replicated logically (their
        # STORAGE is sharded over 'model' via extra_storage — XLA inserts
        # the per-layer all-gather, classic FSDP)
        from repro.core.planner import plan_bound
        dp = tuple(n for n, _ in mesh_axes(mesh) if n != "model")
        return plan_bound({"batch": dp})
    if strategy == "ep2d":
        # 2D expert parallelism on a reshaped 256-chip mesh
        # (data, expert, ffn): experts over their own axis, d_ff over the
        # small ffn axis, attention/embedding over (expert, ffn) combined
        from repro.core.planner import plan_bound
        return plan_bound({
            "batch": ("data",),
            "experts": ("expert",),
            "d_ff": ("ffn",),
            "heads": ("expert", "ffn"),
            "vocab": ("expert", "ffn"),
            "d_ff_shared": ("expert", "ffn"),
        })
    if strategy == "bound":
        # hand table: the non-portable reference (dense-transformer tuned)
        from repro.core.planner import plan_bound
        dp = tuple(n for n, _ in mesh_axes(mesh) if n != "model")
        table = {"batch": dp, "heads": ("model",), "d_ff": ("model",),
                 "vocab": ("model",), "lru": ("model",),
                 "heads_flat": ("model",),
                 "experts": ("model",) if cfg.n_experts >= 16 else ()}
        return plan_bound({k: v for k, v in table.items() if v})
    return plan_bubbles(api.bubble_tree(cfg, shape), axes)


def build_step(cfg, shape, sh):
    """Returns (fn, args_specs, in_shardings, out_shardings, donate)."""
    kind = api.SHAPES[shape]["kind"]
    specs = api.input_specs(cfg, shape)
    pspecs = api.params_specs(cfg)

    if kind == "train":
        acfg = adamw.AdamWConfig()
        loss_fn = api.make_loss_fn(cfg, remat=True)
        pdtype = cfg.pdtype

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt = adamw.apply(grads, opt, acfg,
                                              param_dtype=pdtype)
            return loss, new_params, new_opt

        opt_specs = jax.eval_shape(adamw.init, pspecs)
        args = (pspecs, opt_specs, specs)
        in_sh = (sh["params"], sh["opt"], sh["batch"])
        out_sh = (None, sh["params"], sh["opt"])
        return train_step, args, in_sh, out_sh, (0, 1)

    if kind == "prefill":
        seq = api.SHAPES[shape]["seq"]
        pf = api.make_prefill_fn(cfg, cache_len=seq)
        args = (pspecs, specs)
        in_sh = (sh["params"], sh["batch"])
        return pf, args, in_sh, None, ()

    # decode
    step = api.make_decode_fn(cfg)
    if cfg.enc_layers:
        def serve_step(params, token, states, enc):
            return step(params, token, states, enc)
        args = (pspecs, specs["token"], specs["states"], specs["enc"])
        in_sh = (sh["params"], sh["token"], sh["states"], sh["enc"])
        out_sh = (None, sh["states"])
        return serve_step, args, in_sh, out_sh, (2,)

    def serve_step(params, token, states):
        return step(params, token, states)
    args = (pspecs, specs["token"], specs["states"])
    in_sh = (sh["params"], sh["token"], sh["states"])
    out_sh = (None, sh["states"])
    return serve_step, args, in_sh, out_sh, (2,)


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (fwd)."""
    info = api.SHAPES[shape]
    n = api.lm.count_params(cfg, active_only=True)
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n * tokens
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * info["batch"]          # one token per sequence


def _lower_compile(cfg, shape, mesh, strategy):
    """Lower+compile one exact cell; returns (compiled, plan, shardings)."""
    import dataclasses
    plan = make_plan(cfg, shape, mesh, strategy)
    _, sp, storage = strategy_parts(strategy)
    if sp:
        model_ax = mesh.axis_names[-1]
        cfg = dataclasses.replace(
            cfg, sp_axis=model_ax,
            batch_axes=tuple(plan.axes_of("batch") or ()))
    with mesh:
        sh = shard_mod.shardings(cfg, plan, mesh, shape,
                                 extra_storage=storage)
        fn, args, in_sh, out_sh, donate = build_step(cfg, shape, sh)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return compiled, plan, sh, args


def _metrics(compiled, multi_pod: bool):
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = hlo_mod.collective_bytes(text)
    dcn = hlo_mod.cross_pod_bytes(text, set()) if multi_pod else 0
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm": float(cost.get("bytes accessed", 0.0)),
        "coll": coll.weighted_bytes,
        "dcn": float(dcn),
        "coll_summary": coll.summary(),
    }


def _depth_variant(cfg, groups: int):
    """Config with ``groups`` pattern repeats, scans unrolled (same widths).

    Unrolling puts the block ops in the entry computation where
    cost_analysis can see them (it does not descend into while bodies)."""
    import dataclasses
    L = groups * len(cfg.block_pattern)
    kw = dict(n_layers=L, scan_unroll=True)
    if cfg.enc_layers:
        kw["enc_layers"] = groups
    return dataclasses.replace(cfg, **kw)


def extrapolated_metrics(cfg, shape, mesh, strategy):
    """XLA cost_analysis counts a while-loop body ONCE, not x trip-count,
    so scanned-layer metrics are reconstructed from the exact affine
    relation metric(G) = a + b*G measured at G=1 and G=2.  Collectives
    hoisted out of the loop (stacked-gradient all-reduce) land in the
    b-term through the fit as well because their size is itself ~ G."""
    m1 = _metrics(_lower_compile(_depth_variant(cfg, 1), shape, mesh,
                                 strategy)[0], "pod" in mesh.axis_names)
    m2 = _metrics(_lower_compile(_depth_variant(cfg, 2), shape, mesh,
                                 strategy)[0], "pod" in mesh.axis_names)
    g_full = cfg.n_layers / len(cfg.block_pattern)
    out = {}
    for k in ("flops", "hbm", "coll", "dcn"):
        b = m2[k] - m1[k]
        a = m1[k] - b
        out[k] = a + b * g_full
    out["coll_summary"] = m2["coll_summary"]
    return out


def _mem_estimate(cfg, shape, sh, args):
    """Analytic per-chip memory (CPU backend reports no memory_analysis).

    Arguments are exact (shard bytes of params/opt/batch/state); the
    activation term is the scan-carry residency of the remat policy plus
    the logits buffer."""
    info = api.SHAPES[shape]
    kind = info["kind"]
    arg_bytes = 0
    names = {"train": ("params", "opt", "batch"),
             "prefill": ("params", "batch"),
             "decode": tuple(k for k in ("params", "token", "states", "enc")
                             if k in sh)}[kind]
    spec_map = {"train": args, "prefill": args, "decode": args}
    for name, arg in zip(names, args):
        arg_bytes += shard_mod.sharded_bytes(arg, sh[name])

    act = 0
    if kind in ("train", "prefill"):
        # per-chip carry: (B/NB_dp, S, D) bf16 per layer (train keeps all
        # layer carries live for backward under block-granular remat)
        dp = 1
        pspec = jax.tree.leaves(sh["batch"])[0].spec
        mesh_sizes = dict(zip(
            jax.tree.leaves(sh["batch"])[0].mesh.axis_names,
            jax.tree.leaves(sh["batch"])[0].mesh.devices.shape))
        lead = pspec[0] if len(pspec) else None
        if lead:
            for ax in (lead if isinstance(lead, tuple) else (lead,)):
                dp *= mesh_sizes[ax]
        b_local = max(info["batch"] // dp, 1)
        carry = b_local * info["seq"] * cfg.d_model * 2
        layers = cfg.n_layers * (2 if kind == "train" else 0.1)
        vshard = mesh_sizes.get("model", 1)
        logits = b_local * info["seq"] * max(cfg.vocab // vshard, 1) * 4
        act = int(carry * layers + logits)
    return arg_bytes, act


def run_cell(cfg, shape, mesh, strategy="bubbles", verbose=True):
    t0 = time.time()
    # 1) the deliverable gate: the EXACT config lowers + compiles
    compiled, plan, sh, args = _lower_compile(cfg, shape, mesh, strategy)
    n_chips = mesh.devices.size
    mem = compiled.memory_analysis()   # zeros on CPU backend; kept for TPU
    arg_bytes, act_bytes = _mem_estimate(cfg, shape, sh, args)

    # 2) roofline metrics with scan-depth extrapolation
    mets = extrapolated_metrics(cfg, shape, mesh, strategy)
    rl = hlo_mod.Roofline(
        flops=mets["flops"],
        hbm_bytes=mets["hbm"],
        coll_bytes=mets["coll"],
        dcn_bytes=mets["dcn"],
        model_flops=model_flops(cfg, shape),
        chips=n_chips,
    )
    out = {
        "arch": cfg.name, "shape": shape, "strategy": strategy,
        "mesh": dict(mesh_axes(mesh)), "chips": n_chips,
        "compile_s": round(time.time() - t0, 1),
        "plan": {k: list(v) for k, v in plan.assignment.items()},
        "memory": {
            "argument_bytes_per_chip": arg_bytes,
            "activation_bytes_per_chip_est": act_bytes,
            "total_bytes_per_chip_est": arg_bytes + act_bytes,
            "hbm_per_chip": 16 * 2**30,
            "fits": (arg_bytes + act_bytes) < 16 * 2**30,
            "xla_peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "collectives": mets["coll_summary"],
        "roofline": rl.as_dict(),
    }
    if verbose:
        m = out["memory"]
        print(f"  mem/chip: args={_gb(m['argument_bytes_per_chip'])} "
              f"act~{_gb(m['activation_bytes_per_chip_est'])} "
              f"fits={m['fits']}  flops/chip={rl.flops:.3g} "
              f"coll={_gb(rl.coll_bytes)}")
        print(f"  roofline: compute={rl.t_compute*1e3:.2f}ms "
              f"memory={rl.t_memory*1e3:.2f}ms "
              f"collective={rl.t_collective*1e3:.2f}ms "
              f"-> {rl.bottleneck}-bound, useful={rl.useful_fraction:.2f} "
              f"mfu@roofline={rl.mfu:.2%}")
    return out


def _gb(b):
    return "?" if b is None else f"{b/2**30:.2f}GiB"


def cell_path(arch, shape, multi_pod, strategy) -> Path:
    pods = "pod2" if multi_pod else "pod1"
    return RESULTS / f"{arch}__{shape}__{pods}__{strategy}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(api.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="bubbles",
                    choices=list(STRATEGIES))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(api.SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                ok, why = api.shape_applicable(cfg, shape)
                label = f"{arch} x {shape} x {'2pod' if multi else '1pod'}"
                if not ok:
                    print(f"SKIP {label}: {why}")
                    continue
                path = cell_path(arch, shape, multi, args.strategy)
                if path.exists() and not args.force:
                    print(f"CACHED {label}")
                    continue
                print(f"LOWER {label} [{args.strategy}]")
                try:
                    out = run_cell(cfg, shape, mesh, args.strategy)
                    path.write_text(json.dumps(out, indent=1))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((label, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(f"  {label}: {err.splitlines()[0] if err else err}")
        raise SystemExit(1)
    print("\nall requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
