# launch entry points: mesh.py (topology), dryrun.py (multi-pod lowering),
# train.py / serve.py (drivers).  Import lazily — dryrun must set XLA_FLAGS
# before any jax import.
