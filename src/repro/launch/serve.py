"""Serving driver: continuous-batching engine on the scheduler runtime.

CPU smoke example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 12 --slots 4

``--mode admission`` runs the pre-runtime baseline (no steal/rebalance);
``--stub`` swaps the model for the deterministic numpy stub (no jit) —
the pure-scheduler smoke the CI serving benchmark uses.

``--open-loop`` switches from the closed synthetic batch to an open-loop
arrival trace (``--rate``, ``--trace-steps``, ``--process``): requests
arrive on their own clock with SLA classes and heavy-tailed lengths, and
the run prints per-class TTFT/per-token percentiles plus goodput-under-
SLA.  ``--sla`` (default with --open-loop) schedules by class (WDRR
admission + demotion; add ``--preempt`` to let interactive backlog park
batch gangs); ``--no-sla`` is the hold-the-slot FIFO baseline judged by
the same SLOs.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.serving import (SLA_CLASSES, ServingEngine, StubModelBackend,
                           drive, make_trace)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", default="runtime",
                    choices=("runtime", "admission"))
    ap.add_argument("--stub", action="store_true",
                    help="deterministic numpy model stub (no jit compile)")
    ap.add_argument("--pods", type=int, default=1,
                    help="shard the slot fleet across this many pods "
                         "(DCN-priced steals between them)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="hosts per pod; gangs are routed home round-robin")
    ap.add_argument("--hbm-budget", type=float, default=None,
                    help="KV byte budget per page group (1 unit = 1 "
                         "resident request); full groups refuse loot")
    ap.add_argument("--per-host-decode", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="drive one decode_step per host batch (one jit "
                         "per host, per-host step/occupancy ledgers); "
                         "--no-per-host-decode falls back to the single "
                         "global batch.  Streams are identical either way")
    ap.add_argument("--wave-prefill", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="prefill same-length fresh prompts of one "
                         "admission wave in a single batched call per "
                         "host; --no-wave-prefill runs the per-request "
                         "prefill loop.  Streams are identical either way")
    ap.add_argument("--dcn-rebalance", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="quote re-spreads per boundary crossed and buy "
                         "host-local ones when machine-wide moves are "
                         "overpriced; --no-dcn-rebalance keeps the "
                         "flat-quoted machine-wide re-spread")
    ap.add_argument("--open-loop", action="store_true",
                    help="drive an open-loop arrival trace (SLA classes, "
                         "heavy-tailed lengths) instead of the closed "
                         "synthetic batch; prints per-class latency "
                         "percentiles and goodput-under-SLA")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="open-loop mean arrivals per engine step")
    ap.add_argument("--trace-steps", type=int, default=96,
                    help="open-loop arrival window in engine steps")
    ap.add_argument("--process", default="poisson",
                    choices=("poisson", "bursty", "diurnal"),
                    help="open-loop arrival process")
    ap.add_argument("--sla", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="schedule open-loop traffic by SLA class (WDRR "
                         "admission + multilevel-feedback demotion); "
                         "--no-sla holds slots in arrival order (FIFO "
                         "baseline, judged by the same SLOs)")
    ap.add_argument("--preempt", action="store_true",
                    help="let interactive backlog park a batch-tier "
                         "gang's KV (park/splice, no re-prefill) when "
                         "every slot is held (needs --sla)")
    args = ap.parse_args(argv)

    if args.stub:
        cfg = params = None
        backend = StubModelBackend()
    else:
        import jax
        from repro.configs import ARCHS, get_config
        from repro.models import api
        if args.arch not in ARCHS:
            raise SystemExit(f"unknown arch {args.arch!r}")
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        if cfg.enc_layers:
            raise SystemExit("enc-dec serving path: use examples/serve_batch.py")
        params = api.init(cfg, jax.random.PRNGKey(args.seed))
        backend = None                     # default JaxModelBackend

    rng = np.random.default_rng(args.seed)
    vocab = cfg.vocab if cfg is not None else 251
    sla = SLA_CLASSES if (args.open_loop and args.sla) else None
    eng = ServingEngine(cfg, params, n_slots=args.slots,
                        cache_len=args.cache_len, backend=backend,
                        mode=args.mode, pods=args.pods, hosts=args.hosts,
                        hbm_budget=args.hbm_budget,
                        per_host_decode=args.per_host_decode,
                        wave_prefill=args.wave_prefill,
                        dcn_rebalance=args.dcn_rebalance,
                        sla_classes=sla, preempt=args.preempt)

    if args.open_loop:
        trace = make_trace(steps=args.trace_steps, rate=args.rate,
                           seed=args.seed, process=args.process,
                           vocab=vocab)
        t0 = time.time()
        drive(eng, trace)
        dt = time.time() - t0
        toks = sum(len(r.out_tokens) for r in eng.completed)
        print(f"open-loop: {len(eng.completed)}/{len(trace)} requests, "
              f"{toks} tokens in {dt:.1f}s ({eng.steps} engine steps, "
              f"{'sla' if sla else 'fifo'} admission)")
        summary = eng.latency_summary()
        for name, row in sorted(summary["classes"].items()):
            print(f"  {name:<12} n={row['n']:<4} "
                  f"ttft p50/p99 {row['ttft_p50']:.0f}/{row['ttft_p99']:.0f} "
                  f"tok p50/p99 {row['tok_p50']:.1f}/{row['tok_p99']:.1f}")
        g = summary["goodput"]
        print(f"  goodput-under-SLA {g['good']}/{g['total']} "
              f"({g['frac']:.3f})")
        print("counters:", eng.counters())
        assert len(eng.completed) == len(trace)
        return 0

    n_hosts = args.pods * args.hosts
    homes = [c.name for c in eng.topo.components("host")] \
        if n_hosts > 1 else [None]

    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, vocab, size=args.prompt_len)
        # every 4th request pair shares a gang (prefix-affine group);
        # gangs are routed to a home host round-robin (cross-host
        # admission), lone requests stay on the global list
        gang = f"g{i//4}" if i % 2 == 0 else None
        home = homes[(i // 4) % len(homes)] if gang is not None else None
        eng.submit(prompt, args.new_tokens, prio=i % 3, gang=gang,
                   home=home)

    done = eng.run(max_steps=args.requests * args.new_tokens * 4)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"completed {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s, {eng.steps} engine steps)")
    print("counters:", eng.counters())
    # per-host execution ledger: decode calls each host batch actually ran
    # and its mean occupancy — the skew view per-host decode exists for
    for h, (calls, occ) in enumerate(zip(eng.stats.host_decode_steps,
                                         eng.stats.host_active_slots)):
        lo, hi = eng._exec_groups[h]
        mean = occ / calls if calls else 0.0
        print(f"  host batch {h} (slots {lo}-{hi - 1}): "
              f"{calls} decode steps, mean occupancy {mean:.2f}")
    assert len(done) == args.requests
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
