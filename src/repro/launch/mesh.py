"""Production meshes.

All constructors are FUNCTIONS so importing this module never touches jax
device state (device count is locked at first jax init — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)} — run under launch/dryrun.py which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[list] = None):
    """Arbitrary mesh over the first prod(shape) devices."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    devices = (devices or jax.devices())[:n]
    return jax.sharding.Mesh(np.asarray(devices).reshape(tuple(shape)), axes)


def single_device_mesh(axes: Sequence[str] = ("data", "model")):
    """1x1 mesh for CPU tests of the sharded code paths."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape((1,) * len(axes)), axes)


def mesh_axes(mesh) -> list[tuple[str, int]]:
    return list(zip(mesh.axis_names, mesh.devices.shape))
