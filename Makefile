# Repro of "A Flexible Thread Scheduler for Hierarchical Multiprocessor
# Machines" — developer/CI entry points.
#
#   make test         tier-1 gate: the full pytest suite (hypothesis optional;
#                     tests/_hypothesis_shim.py covers clean environments)
#   make lint         fast syntax gate: byte-compile src/tests/benchmarks +
#                     docs-reference check (README/docs code pointers resolve)
#   make bench-smoke  seconds-scale benchmark sanity run (Table 2 conduction
#                     + imbalanced/thrash stealing rows + small Fig 5 sizes);
#                     writes machine-readable BENCH_smoke.json
#   make bench-gate   bench-smoke + regression check against the committed
#                     benchmarks/baseline_smoke.json (>10% speedup drop fails)
#   make serve-gate   stub-model serving benchmarks alone (gang + open-loop
#                     SLA + elastic + agentic rows; seconds, no jax) gated
#                     against the serve/ baseline rows
#   make jax-serve-gate  real-model serving lane: reduced zoo configs
#                     behind the dense AND paged jax backends (streams
#                     asserted identical, zero pool copies asserted);
#                     tok/s rows gated with the wide throughput band
#                     against benchmarks/baseline_jax.json
#   make golden-check regenerate the golden traces (simulator + serving
#                     engine) and fail on any drift
#   make bench        the full paper tables (slow: includes wall-clock
#                     Table 1 and the roofline dry-run)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint bench-smoke bench-gate serve-gate jax-serve-gate \
        golden-check bench

# tier-1 skips tests marked slow (the 7-minute ep_a2a compile test runs
# in its own non-required CI lane); override PYTEST_ARGS to change the cut
PYTEST_ARGS ?= -m "not slow"
test:
	$(PYTHON) -m pytest -x -q $(PYTEST_ARGS)

lint:
	$(PYTHON) -m compileall -q src tests benchmarks
	$(PYTHON) benchmarks/check_docs.py

bench-smoke:
	$(PYTHON) benchmarks/run.py --smoke --json BENCH_smoke.json

bench-gate: bench-smoke
	$(PYTHON) benchmarks/check_regression.py benchmarks/baseline_smoke.json BENCH_smoke.json

# order matters: serve_gangs' merge replaces every serve/ row, so the
# open-loop, elastic and agentic merges (which replace only their own
# rows) must run after it
serve-gate:
	$(PYTHON) benchmarks/serve_gangs.py --smoke --json BENCH_serve.json
	$(PYTHON) benchmarks/serve_open_loop.py --smoke --json BENCH_serve.json
	$(PYTHON) benchmarks/serve_elastic.py --smoke --json BENCH_serve.json
	$(PYTHON) benchmarks/serve_agentic.py --smoke --json BENCH_serve.json
	$(PYTHON) benchmarks/check_regression.py benchmarks/baseline_smoke.json BENCH_serve.json --prefix serve/

jax-serve-gate:
	$(PYTHON) benchmarks/serve_jax.py --smoke --json BENCH_jax.json
	$(PYTHON) benchmarks/check_regression.py benchmarks/baseline_jax.json BENCH_jax.json --prefix serve/jax_

# GOLDEN_OUT / SERVING_GOLDEN_OUT additionally write the regenerated
# dicts there (CI uploads them as the paste-ready artifacts on drift)
golden-check:
	$(PYTHON) tests/test_golden.py --check $(if $(GOLDEN_OUT),--out $(GOLDEN_OUT))
	$(PYTHON) tests/test_serving_golden.py --check $(if $(SERVING_GOLDEN_OUT),--out $(SERVING_GOLDEN_OUT))

bench:
	$(PYTHON) benchmarks/run.py
