# Repro of "A Flexible Thread Scheduler for Hierarchical Multiprocessor
# Machines" — developer/CI entry points.
#
#   make test         tier-1 gate: the full pytest suite (hypothesis optional;
#                     tests/_hypothesis_shim.py covers clean environments)
#   make bench-smoke  seconds-scale benchmark sanity run (Table 2 conduction
#                     + imbalanced stealing rows + small Fig 5 sizes)
#   make bench        the full paper tables (slow: includes wall-clock
#                     Table 1 and the roofline dry-run)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench

# PYTEST_ARGS lets CI trim the run (e.g. deselect the 7-minute ep_a2a
# compile test on slow shared runners) without changing the local gate
test:
	$(PYTHON) -m pytest -x -q $(PYTEST_ARGS)

bench-smoke:
	$(PYTHON) benchmarks/run.py --smoke

bench:
	$(PYTHON) benchmarks/run.py
