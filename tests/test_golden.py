"""Golden-trace regression tests for the simulator + scheduler stack.

Every run here is fully deterministic: jitter comes from a blake2b hash of
(task id, cycle), task ids restart from 0 via ``reset_ids()``, and the
policies contain no RNG.  The snapshots below pin the observable behaviour
(simulated time, thread migrations, next-touch data migrations, steals,
mean lookup steps) for every policy on the balanced stripes, the
imbalanced (uneven groups + skew) stripes, and the fibonacci workload —
so a future refactor cannot silently change scheduling behaviour.

To regenerate after an *intentional* behaviour change:

    PYTHONPATH=src python tests/test_golden.py

and paste the printed dict over ``GOLDEN``.  CI's golden-drift job runs

    PYTHONPATH=src python tests/test_golden.py --check

which regenerates every snapshot and fails (exit 1, printing the drifted
entries) if any differs from the committed dict — catching nondeterminism
or accidental behaviour changes sneaking into the scheduler.
"""

import pytest

from repro.core import (POLICIES, Simulator, fibonacci_workload,
                        imbalanced_stripes_workload, novascale_16, reset_ids,
                        stripes_workload)

BALANCED = dict(n_threads=16, work=50.0, group=4)

# bubble-family policies see the grouped/bubbled tree; flat-list policies
# get the flat equivalent (same stripes, same work)
BUBBLY = ("bubbles", "steal", "adaptive")


def _workload(case: str, policy: str):
    if case == "stripes_bal":
        kw = dict(BALANCED)
        if policy not in BUBBLY:
            kw["group"] = None
        return stripes_workload(**kw), 3
    if case == "stripes_imb":
        return imbalanced_stripes_workload(work=50.0,
                                           flat=policy not in BUBBLY), 3
    assert case == "fib"
    return fibonacci_workload(32, with_bubbles=policy in BUBBLY,
                              group_size=4), 1


def simulate(case: str, policy: str) -> dict:
    reset_ids()
    topo = novascale_16()
    kw = {"disorder": 4.0} if policy == "simple" else {}
    pol = POLICIES[policy](topo, **kw)
    root, cycles = _workload(case, policy)
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25, contention=0.5)
    r = sim.run(root, cycles=cycles)
    return {
        "time": round(r.time, 6),
        "migrations": r.migrations,
        "data_migrations": r.data_migrations,
        "steals": r.extra["steals"],
        "lookup_steps": round(r.lookup_steps, 6),
    }


CASES = ["stripes_bal", "stripes_imb", "fib"]


# ---------------------------------------------------------------------------
# snapshots (regenerate with: PYTHONPATH=src python tests/test_golden.py)
# ---------------------------------------------------------------------------

GOLDEN = {
    ('stripes_bal', 'bound'): {'time': 155.0, 'migrations': 0,
                               'data_migrations': 0, 'steals': 0,
                               'lookup_steps': 0.0},
    ('stripes_bal', 'bubbles'): {'time': 160.0, 'migrations': 0,
                                 'data_migrations': 0, 'steals': 0,
                                 'lookup_steps': 3.0},
    ('stripes_bal', 'percpu'): {'time': 155.0, 'migrations': 0,
                                'data_migrations': 0, 'steals': 0,
                                'lookup_steps': 10.704918},
    ('stripes_bal', 'simple'): {'time': 226.0, 'migrations': 0,
                                'data_migrations': 0, 'steals': 0,
                                'lookup_steps': 0.121678},
    ('stripes_bal', 'steal'): {'time': 160.0, 'migrations': 0,
                               'data_migrations': 0, 'steals': 0,
                               'lookup_steps': 3.0},
    # adaptive under ZERO_COST degrades into plain steal (the cost-benefit
    # trigger never fires when stealing is free) — same traces as 'steal'
    ('stripes_bal', 'adaptive'): {'time': 160.0, 'migrations': 0,
                                  'data_migrations': 0, 'steals': 0,
                                  'lookup_steps': 3.0},
    ('stripes_imb', 'adaptive'): {'time': 484.0, 'migrations': 18,
                                  'data_migrations': 11, 'steals': 24,
                                  'lookup_steps': 3.0},
    ('fib', 'adaptive'): {'time': 22.0, 'migrations': 0,
                          'data_migrations': 0, 'steals': 0,
                          'lookup_steps': 3.0},
    ('stripes_imb', 'bound'): {'time': 525.0, 'migrations': 0,
                               'data_migrations': 0, 'steals': 0,
                               'lookup_steps': 0.0},
    ('stripes_imb', 'bubbles'): {'time': 581.0, 'migrations': 18,
                                 'data_migrations': 0, 'steals': 24,
                                 'lookup_steps': 3.0},
    ('stripes_imb', 'percpu'): {'time': 525.0, 'migrations': 0,
                                'data_migrations': 0, 'steals': 0,
                                'lookup_steps': 15.76129},
    ('stripes_imb', 'simple'): {'time': 752.0, 'migrations': 0,
                                'data_migrations': 0, 'steals': 0,
                                'lookup_steps': 0.062669},
    ('stripes_imb', 'steal'): {'time': 484.0, 'migrations': 18,
                               'data_migrations': 11, 'steals': 24,
                               'lookup_steps': 3.0},
    ('fib', 'bound'): {'time': 38.0, 'migrations': 0,
                       'data_migrations': 0, 'steals': 0,
                       'lookup_steps': 0.0},
    ('fib', 'bubbles'): {'time': 22.0, 'migrations': 0,
                         'data_migrations': 0, 'steals': 0,
                         'lookup_steps': 3.0},
    ('fib', 'percpu'): {'time': 30.0, 'migrations': 0,
                        'data_migrations': 0, 'steals': 0,
                        'lookup_steps': 12.047619},
    ('fib', 'simple'): {'time': 34.0, 'migrations': 0,
                        'data_migrations': 0, 'steals': 0,
                        'lookup_steps': 0.796178},
    ('fib', 'steal'): {'time': 22.0, 'migrations': 0,
                       'data_migrations': 0, 'steals': 0,
                       'lookup_steps': 3.0},
}


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_golden_trace(case: str, policy: str):
    got = simulate(case, policy)
    want = GOLDEN[(case, policy)]
    for key in ("migrations", "data_migrations", "steals"):
        assert got[key] == want[key], (case, policy, key, got, want)
    assert got["time"] == pytest.approx(want["time"], rel=1e-9), \
        (case, policy, got, want)
    assert got["lookup_steps"] == pytest.approx(want["lookup_steps"],
                                                rel=1e-6), (case, policy)


def generate() -> dict:
    out = {}
    for case in CASES:
        for policy in sorted(POLICIES):
            out[(case, policy)] = simulate(case, policy)
    return out


def format_golden(snapshots: dict) -> str:
    lines = ["GOLDEN = {"]
    lines += [f"    {k!r}: {v!r}," for k, v in snapshots.items()]
    lines.append("}")
    return "\n".join(lines)


def check_drift(out_path=None) -> int:
    """Regenerate all snapshots (once); report any that differ from GOLDEN.

    ``out_path`` additionally writes the regenerated dict there — CI
    uploads it as an artifact so a failing run hands you the paste-ready
    replacement without a second generation pass."""
    regen = generate()
    if out_path:
        with open(out_path, "w") as f:
            f.write(format_golden(regen) + "\n")
    drifted = {k: (GOLDEN.get(k), v) for k, v in regen.items()
               if GOLDEN.get(k) != v}
    missing = sorted(k for k in GOLDEN if k not in regen)
    if not drifted and not missing:
        print(f"golden traces stable: {len(regen)} snapshots match")
        return 0
    for k, (want, got) in sorted(drifted.items()):
        print(f"DRIFT {k}:\n  committed:   {want!r}\n  regenerated: {got!r}")
    for k in missing:
        print(f"MISSING {k}: committed but no longer generated")
    print(f"{len(drifted)} drifted, {len(missing)} missing — if intentional, "
          "regenerate with `PYTHONPATH=src python tests/test_golden.py` and "
          "paste over GOLDEN")
    return 1


if __name__ == "__main__":
    import sys
    argv = sys.argv[1:]
    if "--check" in argv:
        out = None
        if "--out" in argv:
            out = argv[argv.index("--out") + 1]
        sys.exit(check_drift(out))
    print(format_golden(generate()))
