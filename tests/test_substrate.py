"""Substrate tests: optimizer, compression, checkpoint, data, serving, FT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api


KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def _setup(self):
        from repro.optim import adamw
        params = {"w": jnp.ones((4, 8), jnp.float32),
                  "b": jnp.zeros((8,), jnp.float32)}
        return adamw, params, adamw.init(params)

    def test_descends_quadratic(self):
        adamw, params, state = self._setup()
        cfg = __import__("repro.optim.adamw", fromlist=["AdamWConfig"]) \
            .AdamWConfig(lr=0.1, warmup=1, weight_decay=0.0)
        target = {"w": jnp.full((4, 8), 3.0), "b": jnp.full((8,), -1.0)}

        def loss(p):
            return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

        p = params
        for _ in range(200):
            g = jax.grad(loss)(p)
            p, state = adamw.apply(g, state, cfg, param_dtype=jnp.float32)
        assert float(loss(p)) < 1e-2

    def test_master_not_aliased(self):
        adamw, params, state = self._setup()
        # buffers must be distinct (donation safety)
        assert state.master["w"].unsafe_buffer_pointer() != \
            params["w"].unsafe_buffer_pointer()

    def test_grad_clip(self):
        from repro.optim import adamw
        g = {"w": jnp.full((10,), 1e6)}
        assert float(adamw.global_norm(g)) > 1e6


class TestCompression:
    def test_quantize_roundtrip_small_error(self):
        from repro.optim import compression as C
        g = jax.random.normal(KEY, (256,), jnp.float32) * 0.01
        q, s = C.quantize(g)
        back = C.dequantize(q, s)
        assert q.dtype == jnp.int8
        assert float(jnp.abs(back - g).max()) < float(jnp.abs(g).max()) / 100

    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated error of repeated compression of a
        CONSTANT gradient vanishes (the residual re-injects)."""
        from repro.optim import compression as C
        g = {"w": jnp.array([1e-4, 3e-3, -2e-3, 5e-5], jnp.float32)}
        ef = C.init(g)
        total_sent = jnp.zeros((4,))
        for _ in range(50):
            qs, ef = C.compress_tree(g, ef)
            total_sent = total_sent + C.decompress_tree(qs)["w"]
        mean_sent = total_sent / 50
        np.testing.assert_allclose(np.asarray(mean_sent), np.asarray(g["w"]),
                                   rtol=0.05, atol=1e-6)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro import checkpoint as ckpt
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "n": {"b": jnp.ones((2,), jnp.int32)}}
        ckpt.save(tmp_path, 7, tree, extra={"note": "x"})
        assert ckpt.latest_step(tmp_path) == 7
        got, man = ckpt.restore(tmp_path, 7, tree)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))
        assert man["extra"]["note"] == "x"

    def test_atomicity_tmp_never_visible(self, tmp_path):
        from repro import checkpoint as ckpt
        tree = {"a": jnp.zeros((2,))}
        ckpt.save(tmp_path, 1, tree)
        ckpt.save(tmp_path, 2, tree)
        names = {d.name for d in tmp_path.iterdir()}
        assert names == {"step_00000001", "step_00000002"}

    def test_restart_resumes_training(self, tmp_path):
        """Full restart: train 4 steps, save; new process-state restores and
        continues deterministically."""
        from repro import checkpoint as ckpt
        from repro.optim import adamw
        cfg = get_config("yi-6b").reduced(n_layers=1)
        params = api.init(cfg, KEY)
        acfg = adamw.AdamWConfig(lr=1e-3, warmup=1)
        opt = adamw.init(params)
        loss_fn = api.make_loss_fn(cfg)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}

        def step(p, o):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            p, o = adamw.apply(g, o, acfg, param_dtype=jnp.float32)
            return loss, p, o

        for _ in range(2):
            _, params, opt = step(params, opt)
        ckpt.save(tmp_path, 2, params)
        ckpt.save(tmp_path / "opt", 2, opt)
        _, p_cont, o_cont = step(params, opt)

        p2, _ = ckpt.restore(tmp_path, 2, params)
        o2, _ = ckpt.restore(tmp_path / "opt", 2, opt)
        _, p_rest, o_rest = step(p2, o2)
        for a, b in zip(jax.tree.leaves(p_cont), jax.tree.leaves(p_rest)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


class TestData:
    def test_deterministic_and_shard_consistent(self):
        from repro.data import DataConfig, ShardedTokenStream
        c = DataConfig(vocab=100, seq_len=8, global_batch=8,
                       n_pods=2, hosts_per_pod=2)
        s1 = ShardedTokenStream(c)
        s2 = ShardedTokenStream(c)
        g = s1.global_batch(3)
        # shards tile the global batch exactly
        parts = []
        for p in range(2):
            for h in range(2):
                rows = s2.host_rows(p, h)
                parts.append(s2.global_batch(3)["tokens"][rows])
        np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])

    def test_prefetch(self):
        from repro.data import DataConfig, PrefetchBuffer, ShardedTokenStream
        c = DataConfig(vocab=50, seq_len=4, global_batch=2)
        it = PrefetchBuffer(ShardedTokenStream(c).shard(), depth=2)
        b1, b2 = next(it), next(it)
        assert b1["tokens"].shape == (2, 4)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))

    def test_labels_are_shifted_tokens(self):
        from repro.data import DataConfig, ShardedTokenStream
        c = DataConfig(vocab=100, seq_len=8, global_batch=2)
        b = ShardedTokenStream(c).global_batch(0)
        # labels[t] is the next token of an underlying (seq+1) stream
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestServing:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        cfg = get_config("yi-6b").reduced(n_layers=1)
        params = api.init(cfg, KEY)
        return cfg, params

    def test_completes_all_requests(self, engine_setup):
        from repro.serving import ServingEngine
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, n_slots=2, cache_len=64)
        rng = np.random.default_rng(0)
        for i in range(5):
            eng.submit(rng.integers(1, cfg.vocab, 8), 4, prio=i % 2)
        done = eng.run(max_steps=200)
        assert len(done) == 5
        for r in done:
            assert len(r.out_tokens) == 4

    def test_priority_served_first(self, engine_setup):
        from repro.serving import ServingEngine
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, n_slots=1, cache_len=64)
        rng = np.random.default_rng(0)
        lo = eng.submit(rng.integers(1, cfg.vocab, 8), 2, prio=0)
        hi = eng.submit(rng.integers(1, cfg.vocab, 8), 2, prio=9)
        done = eng.run(max_steps=100)
        assert done[0].rid == hi            # high-prio finished first

    def test_greedy_matches_reference_decode(self, engine_setup):
        """Engine output must equal standalone prefill+greedy decode."""
        from repro.serving import ServingEngine
        cfg, params = engine_setup
        rng = np.random.default_rng(1)
        prompt = rng.integers(1, cfg.vocab, 8)
        eng = ServingEngine(cfg, params, n_slots=2, cache_len=64)
        eng.submit(prompt, 4)
        done = eng.run(max_steps=50)
        got = done[0].out_tokens

        logits, st = api.make_prefill_fn(cfg, 64)(
            params, {"tokens": jnp.asarray(prompt[None])})
        want = [int(jnp.argmax(logits, -1)[0])]
        dec = api.make_decode_fn(cfg)
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        for _ in range(3):
            logits, st = dec(params, tok, st)
            want.append(int(jnp.argmax(logits, -1)[0]))
            tok = jnp.asarray([[want[-1]]], jnp.int32)
        assert got == want


class TestFaultTolerance:
    def test_straggler_detector(self):
        from repro.distributed.fault_tolerance import StragglerDetector
        d = StragglerDetector(threshold=1.5)
        for _ in range(5):
            for h in ("a", "b", "c", "d"):
                d.observe(h, 1.0 if h != "d" else 3.0)
        assert d.stragglers() == ["d"]

    def test_fleet_shrink_remesh(self):
        from repro.distributed.fault_tolerance import FleetSpec
        spec = FleetSpec(pods=2, data=4, model=2,
                         dead_pods=frozenset({1}))
        assert spec.alive_shape() == (4, 2)
        assert spec.alive_axes() == ("data", "model")

    def test_replan_after_shrink(self):
        from repro.distributed.fault_tolerance import replan, rebuild_mesh, \
            FleetSpec
        cfg = get_config("yi-6b")
        tree = api.bubble_tree(cfg, "train_4k")
        # 1x1 mesh on CPU: plan must still resolve (everything replicated
        # except what fits size-1 axes)
        spec = FleetSpec(pods=1, data=1, model=1)
        mesh = rebuild_mesh(spec)
        plan = replan(tree, mesh)
        assert "batch" in plan.assignment

    def test_elastic_restart_roundtrip(self, tmp_path):
        """Checkpoint written under one layout restores onto another mesh."""
        from repro import checkpoint as ckpt
        from repro.distributed.fault_tolerance import FleetSpec, \
            elastic_restart
        from repro.distributed import sharding as shard_mod
        cfg = get_config("yi-6b").reduced(n_layers=1)
        params = api.init(cfg, KEY)
        ckpt.save(tmp_path, 5, params)
        tree = api.bubble_tree(cfg, "train_4k")

        def mk(plan, mesh):
            return jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                shard_mod.param_specs(cfg, plan, mesh))

        mesh, plan, restored, step = elastic_restart(
            tree, FleetSpec(pods=1, data=1, model=1), tmp_path, params,
            make_shardings=mk)
        assert step == 5
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(restored)[0]),
            np.asarray(jax.tree.leaves(params)[0]))

    def test_regenerate_straggler_bubbles(self):
        from repro.core import BubbleScheduler, novascale_16, bubble, thread
        from repro.distributed.fault_tolerance import \
            regenerate_straggler_bubbles
        sched = BubbleScheduler(novascale_16())
        b = bubble(*[thread(5.0) for _ in range(4)])
        # place it on cpu0's node queue as if it sank there
        node0 = sched.topo.components("node")[0]
        sched.queues.queue_of(node0).push(b)
        moved = regenerate_straggler_bubbles(sched, [0])
        assert moved == 1
        assert len(sched.queues.global_queue()) == 1
