"""shard_map all-to-all EP prototype: exactness + explicit-collective HLO.

Runs in a subprocess (the EP path needs 8 placeholder devices; the main
test process keeps the single real device per conftest policy).
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# ~7 minutes of XLA compile on a shared runner: out of tier-1, into the
# dedicated slow lane (Makefile PYTEST_ARGS / ci.yml "slow" job)
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    from repro.distributed.ep_a2a import make_ep_ffn

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("expert",))
    E, K, D, F, T, cap = 8, 2, 16, 32, 32, 16
    key = jax.random.PRNGKey(0)
    wi = jax.random.normal(key, (E, D, F)) * 0.05
    wg = jax.random.normal(jax.random.PRNGKey(1), (E, D, F)) * 0.05
    wo = jax.random.normal(jax.random.PRNGKey(2), (E, F, D)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (T, D))
    logits = x @ (jax.random.normal(jax.random.PRNGKey(4), (D, E)) * 0.3)
    gv, gi = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
    gv = gv / gv.sum(-1, keepdims=True)

    def ffn_apply(wi, wg, wo, buf):
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        return jnp.einsum("ecf,efd->ecd", h * jax.nn.silu(g), wo)

    ep = make_ep_ffn(mesh, "expert", E, K, ffn_apply, cap_per_pair=cap)
    with mesh:
        sh = NamedSharding(mesh, P("expert"))
        args = [jax.device_put(a, sh) for a in (wi, wg, wo, x, gi, gv)]
        y = jax.jit(ep)(*args)
        txt = jax.jit(ep).lower(*args).compile().as_text()

    ref = np.zeros((T, D), np.float32)
    for t in range(T):
        for k in range(K):
            e = int(gi[t, k])
            v = x[t] @ wi[e]; g = x[t] @ wg[e]
            ref[t] += float(gv[t, k]) * np.asarray(
                (v * jax.nn.silu(g)) @ wo[e])
    err = np.abs(np.asarray(y) - ref).max()
    assert err < 1e-4, err
    assert txt.count("all-to-all(") >= 2
    print("OK")
""")


def test_ep_a2a_exact_and_explicit_collectives():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
