"""Per-arch smoke tests (reduced configs, CPU) + model-level invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import api, lm
from repro.models.layers import unembed

KEY = jax.random.PRNGKey(0)


def _train_batch(c, B=2, S=32):
    if c.enc_layers or c.frontend == "audio":
        return {"frontend_embeds": jnp.full((B, S, c.d_model), 0.01, jnp.float32),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    if c.frontend == "vision":
        P = min(c.frontend_tokens, S - 16)
        return {"frontend_embeds": jnp.full((B, P, c.d_model), 0.01, jnp.float32),
                "tokens": jnp.ones((B, S - P), jnp.int32),
                "labels": jnp.ones((B, S - P), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step(self, arch):
        c = get_config(arch).reduced()
        params = api.init(c, KEY)
        batch = _train_batch(c)
        loss, grads = jax.value_and_grad(api.make_loss_fn(c))(params, batch)
        assert jnp.isfinite(loss), arch
        leaves = jax.tree.leaves(grads)
        assert leaves
        for g in leaves:
            assert np.isfinite(np.asarray(g, dtype=np.float32)).all(), arch

    def test_prefill_decode_shapes(self, arch):
        c = get_config(arch).reduced()
        params = api.init(c, KEY)
        B, S = 2, 32
        batch = _train_batch(c, B, S)
        batch.pop("labels")
        if c.enc_layers:
            enc, states = api.make_prefill_fn(c, cache_len=S)(params, batch)
            logits, _ = api.make_decode_fn(c)(
                params, jnp.ones((B, 1), jnp.int32), states, enc)
        else:
            logits0, states = api.make_prefill_fn(c, cache_len=S)(params, batch)
            assert logits0.shape == (B, c.vocab)
            logits, _ = api.make_decode_fn(c)(
                params, jnp.ones((B, 1), jnp.int32), states)
        assert logits.shape == (B, c.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_param_dims_cover_params(self, arch):
        c = get_config(arch).reduced()
        shapes = api.params_specs(c)
        dims = api.dims(c)
        flat_s = jax.tree.leaves(shapes)
        flat_d = jax.tree.leaves(dims, is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_s) == len(flat_d)
        for s, d in zip(flat_s, flat_d):
            assert len(s.shape) == len(d), (s.shape, d)

    def test_input_specs_exist_for_applicable_shapes(self, arch):
        c = get_config(arch)
        for shape in api.SHAPES:
            ok, why = api.shape_applicable(c, shape)
            if not ok:
                assert why
                continue
            specs = api.input_specs(c, shape)
            assert specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-3-4b", "rwkv6-3b",
                                  "recurrentgemma-9b", "deepseek-moe-16b"])
def test_prefill_decode_matches_full_forward(arch):
    """Decode with a prefilled cache must equal the full-sequence forward."""
    c = get_config(arch).reduced()
    params = api.init(c, KEY)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S + 1), 0, c.vocab)
    logits_p, states = api.make_prefill_fn(c, cache_len=S + 4)(
        params, {"tokens": toks[:, :S]})
    logits_d, _ = api.make_decode_fn(c)(params, toks[:, S:S + 1], states)

    h = lm._inputs_to_h(params, {"tokens": toks}, c)
    pos = jnp.broadcast_to(jnp.arange(S + 1), (B, S + 1))
    hN, _, _ = lm.backbone(params, h, pos, c)
    full = unembed(params["lm_head"], hN, c.logits_softcap)

    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, S - 1]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, S]), atol=2e-4)


def test_sliding_window_limits_attention():
    """With window w, logits must not depend on tokens older than w."""
    c = get_config("h2o-danube-3-4b").reduced(window=8)
    params = api.init(c, KEY)
    B, S = 1, 20
    t1 = jax.random.randint(KEY, (B, S), 0, c.vocab)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % c.vocab)   # differ outside window
    f = api.make_prefill_fn(c, cache_len=S)
    l1, _ = f(params, {"tokens": t1})
    l2, _ = f(params, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_causality():
    """Changing future tokens must not affect past logits."""
    c = get_config("yi-6b").reduced()
    params = api.init(c, KEY)
    B, S = 1, 16
    toks = jax.random.randint(KEY, (B, S), 0, c.vocab)
    h = lm._inputs_to_h(params, {"tokens": toks}, c)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out1, _, _ = lm.backbone(params, h, pos, c)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 3) % c.vocab)
    h2 = lm._inputs_to_h(params, {"tokens": toks2}, c)
    out2, _, _ = lm.backbone(params, h2, pos, c)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


def test_moe_routes_to_multiple_experts():
    c = get_config("deepseek-moe-16b").reduced()
    params = api.init(c, KEY)
    from repro.models.moe import moe_ffn
    x = jax.random.normal(KEY, (2, 16, c.d_model), jnp.float32)
    blk = params["stage0"]["b0_attn"]["ffn"]
    one = jax.tree.map(lambda a: a[0], blk)
    y, aux = moe_ffn(one, x, c)
    assert y.shape == x.shape
    assert float(aux) > 0.0


def test_param_counts_match_published():
    totals = {
        "grok-1-314b": (300e9, 330e9),
        "deepseek-moe-16b": (15e9, 18e9),
        "yi-6b": (5.5e9, 6.5e9),
        "internlm2-20b": (18e9, 21e9),
        "h2o-danube-3-4b": (3.5e9, 4.3e9),
        "rwkv6-3b": (2.7e9, 3.3e9),
        "llava-next-34b": (32e9, 36e9),
    }
    for arch, (lo, hi) in totals.items():
        n = lm.count_params(get_config(arch))
        assert lo < n < hi, (arch, n)
