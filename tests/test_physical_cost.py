"""The physical cost model: bandwidth-priced transfers, straggler-aware
host speed, and HBM-aware gang splitting.

Three rulers turn the abstract steal/rebalance prices into machine
physics, each with a strict backward-compatibility invariant this module
pins:

* **per-byte pricing** — ``StealCostModel.level_table`` entries may carry
  a third element, the per-byte rate of that boundary; every bill then
  scales with the KV bytes a move drags (``bytes_cb``).  With every
  ``per_byte`` zero (or the historical pair form) the prices are
  bit-identical — property-tested over a ``(base, per_byte)`` grid.
* **host speed** — ``speed_cb`` weighs the costed steal survey's victim
  backlog (work / victim speed), refuses drags from faster hosts onto
  slower ones, and divides the LPT rebalance deal's loads by speed.
  Uniform speed selects identically to no callback at all.
* **gang splitting** — an HBM-refused whole gang is quoted a split across
  its host's sibling page groups against park-and-wait, and the engine
  buys the cheaper.  Splitting never changes a decode stream.

Satellites pinned here too: the serving cost tables cover every
``slots_topology`` level (S2) and ``PagedJaxModelBackend(hbm_bytes=...)``
sizes its pool from the byte ledger (S1).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (ZERO_COST, BubbleScheduler, StealCostModel,
                        novascale_16, thread)
from repro.serving import (BW_SERVE_COST, SERVE_COST, SERVE_FREE_LEVELS,
                           ServingEngine, StubModelBackend, slots_topology)

# ---------------------------------------------------------------------------
# S3: (base, per_byte) property grid on the cost model itself
# ---------------------------------------------------------------------------

BASES = (0.0, 2.5, 10.0)
RATES = (0.0, 0.125, 1.5)
BYTES = (0.0, 1.0, 7.5, 64.0)


class TestPerBytePricing:
    @pytest.mark.parametrize("base", BASES)
    def test_pair_and_zero_rate_triple_price_identically(self, base):
        """The historical pair form IS the triple form at per_byte=0: every
        price — steal, rebalance move, the free-steals switch — matches
        bit for bit, at any bytes_moved."""
        pair = StealCostModel(lock_penalty=0.5, thread_penalty=0.125,
                              level_table=(("node", base),))
        triple = StealCostModel(lock_penalty=0.5, thread_penalty=0.125,
                                level_table=(("node", base, 0.0),))
        for b in BYTES:
            for dist in (0, 1, 2):
                assert pair.steal_cost(dist, 2, "node", b) == \
                    triple.steal_cost(dist, 2, "node", b)
            assert pair.rebalance_move_cost("node", b) == \
                triple.rebalance_move_cost("node", b)
        assert pair.steals_are_free == triple.steals_are_free
        assert pair.byte_cost("node") == triple.byte_cost("node") == 0.0

    @pytest.mark.parametrize("base", BASES)
    @pytest.mark.parametrize("rate", RATES)
    def test_prices_linear_and_monotone_in_bytes(self, base, rate):
        """cost(bytes) is exactly base-part + rate * bytes: nondecreasing,
        and the increment between any two byte counts is the rate times
        the byte delta (no hidden rounding or coupling)."""
        cm = StealCostModel(lock_penalty=1.0,
                            level_table=(("node", base, rate),))
        prev = None
        for b in BYTES:
            steal = cm.steal_cost(2, 1, "node", b)
            move = cm.rebalance_move_cost("node", b)
            assert steal == pytest.approx(
                cm.steal_cost(2, 1, "node", 0.0) + rate * b)
            assert move == pytest.approx(
                cm.rebalance_move_cost("node", 0.0) + rate * b)
            if prev is not None:
                assert steal >= prev - 1e-12
            prev = steal
        # un-tabled boundaries never pick up a byte term
        assert cm.steal_cost(2, 1, "cpu", 64.0) == \
            cm.steal_cost(2, 1, "cpu", 0.0)
        assert cm.byte_cost("cpu") == 0.0

    def test_per_byte_alone_makes_steals_costed(self):
        """A nonzero per-byte rate is a price: it must flip the scheduler
        into the costed-survey regime even when every base is zero."""
        assert not StealCostModel(
            level_table=(("node", 0.0, 0.5),)).steals_are_free
        assert StealCostModel(
            level_table=(("node", 0.0, 0.0),)).steals_are_free
        assert ZERO_COST.steals_are_free

    def test_byte_naive_belief_byte_priced_bill(self):
        """The bandwidth harness in unit form: the survey ranks with the
        flat cost_model while the ledger charges the byte-priced
        bill_model — same victim choice, heavier bill."""
        flat = StealCostModel(lock_penalty=1.0, level_penalty=0.5,
                              level_table=(("node", 2.0),))
        bw = dataclasses.replace(flat, level_table=(("node", 2.0, 0.5),))
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=flat, bill_model=bw)
        sched.bytes_cb = lambda task: 8.0
        sched.queues.queue_of(topo.components("node")[3]).push(thread(9.0))
        assert sched._steal_pass(0) is not None
        # belief: flat node crossing;  charge: + 0.5/byte * 8 bytes
        assert sched.stats.last_steal_cost == \
            pytest.approx(1.0 + 2.0 * 2 + 0.5 * 8.0)

    def test_survey_prefers_lighter_bytes_at_equal_distance(self):
        """Byte-priced belief: loot that drags less KV wins work-per-cost
        even against slightly heavier work; the flat belief (per_byte=0)
        keeps the heavier loot."""
        bw = StealCostModel(lock_penalty=1.0,
                            level_table=(("node", 2.0, 1.0),))
        flat = dataclasses.replace(bw, level_table=(("node", 2.0),))
        by_name = {"fat": 20.0, "slim": 1.0}
        for model, want in ((bw, "slim"), (flat, "fat")):
            topo = novascale_16()
            sched = BubbleScheduler(topo, cost_model=model)
            sched.bytes_cb = lambda t: by_name[t.name]
            sched.queues.queue_of(topo.components("node")[2]).push(
                thread(10.0, name="fat"))
            sched.queues.queue_of(topo.components("node")[3]).push(
                thread(9.0, name="slim"))
            got = sched._steal_pass(0)
            assert got is not None and got[1].name == want, model


# ---------------------------------------------------------------------------
# host speed: the survey's rescue preference, the thief-side refusal, the
# speed-weighted LPT deal
# ---------------------------------------------------------------------------

def _speed_by_node(topo, speeds):
    nodes = topo.components("node")
    table = {id(n): s for n, s in zip(nodes, speeds)}

    def speed_of(comp):
        for node in comp.path():
            if id(node) in table:
                return table[id(node)]
        return 1.0
    return speed_of


class TestHostSpeed:
    CM = StealCostModel(lock_penalty=1.0, level_penalty=0.5)

    def test_survey_rescues_slow_victim_backlog(self):
        """Equal work at equal distance: the victim whose host drains it
        slowest has the larger effective backlog and wins the survey."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        sched.speed_cb = _speed_by_node(topo, (1.0, 1.0, 0.25, 1.0))
        sched.queues.queue_of(topo.components("node")[2]).push(
            thread(9.0, name="slow"))
        sched.queues.queue_of(topo.components("node")[3]).push(
            thread(9.0, name="fast"))
        got = sched._steal_pass(0)
        assert got is not None and got[1].name == "slow"

    def test_slow_thief_refuses_faster_victims(self):
        """Work never drains toward a slower host: a straggler's idle cpu
        leaves a faster victim's backlog alone (the victim finishes it
        sooner than the thief ever could)."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        sched.speed_cb = _speed_by_node(topo, (0.25, 1.0, 1.0, 1.0))
        sched.queues.queue_of(topo.components("node")[3]).push(thread(9.0))
        assert sched._steal_pass(0) is None          # cpu 0 is on node 0
        # an equally slow victim is fair game (and still rescued)
        sched.speed_cb = _speed_by_node(topo, (0.25, 1.0, 1.0, 0.25))
        assert sched._steal_pass(0) is not None

    def test_uniform_speed_cb_is_no_callback(self):
        """speed_cb returning 1.0 everywhere must pick the same loot (and
        price it the same) as no callback at all."""
        for cb in (None, lambda comp: 1.0):
            topo = novascale_16()
            sched = BubbleScheduler(topo, cost_model=self.CM)
            sched.speed_cb = cb
            sched.queues.queue_of(topo.components("node")[2]).push(
                thread(4.0, name="light"))
            sched.queues.queue_of(topo.components("node")[3]).push(
                thread(9.0, name="heavy"))
            got = sched._steal_pass(0)
            assert got is not None and got[1].name == "heavy"
            assert sched.stats.last_steal_cost == \
                pytest.approx(1.0 + 0.5 * 2)

    def test_lpt_deal_weighs_loads_by_speed(self):
        """The machine-wide re-spread divides destination loads by host
        speed: a 4x-slower node receives roughly a quarter of the work a
        nominal node does (and exactly the uniform deal at speed 1.0)."""
        def deal(speeds):
            topo = novascale_16()
            sched = BubbleScheduler(topo, cost_model=self.CM)
            if speeds is not None:
                sched.speed_cb = _speed_by_node(topo, speeds)
            for _ in range(16):
                sched.queues.global_queue().push(thread(3.0))
            assert sched.rebalance(0, level="node") == 16
            return [len(sched.queues.queue_of(n))
                    for n in topo.components("node")]
        uniform, flat = deal((1.0,) * 4, ), deal(None)
        assert uniform == flat                      # speed 1.0: identical
        skewed = deal((0.25, 1.0, 1.0, 1.0))
        assert skewed[0] < min(skewed[1:])          # straggler dealt least
        assert sum(skewed) == 16                    # nothing lost
        assert skewed[0] <= uniform[0] // 2


# ---------------------------------------------------------------------------
# engine integration: the straggler execution model and gang splitting
# ---------------------------------------------------------------------------

def _submit_mixed(eng):
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(10):
        eng.submit(rng.integers(1, 250, 8), 6, home="page0")
        n += 1
    for _ in range(6):
        eng.submit(rng.integers(1, 250, 8), 10, home="page1")
        n += 1
    return n


def _streams(eng):
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


class TestStragglerEngine:
    def _run(self, **kw):
        eng = ServingEngine(None, None, n_slots=8, hosts=2,
                            backend=StubModelBackend(), mode="runtime",
                            cost_model=SERVE_COST, **kw)
        n = _submit_mixed(eng)
        eng.run(max_steps=4000)
        assert len(eng.completed) == n
        return eng

    def test_uniform_speed_is_bit_identical(self):
        """host_speed=(1, 1) must reproduce the no-host_speed engine
        exactly: steps, streams, and every counter."""
        base = self._run()
        unif = self._run(host_speed=(1.0, 1.0))
        assert unif.steps == base.steps
        assert _streams(unif) == _streams(base)
        assert unif.counters() == base.counters()

    def test_slow_host_spans_steps_streams_unchanged(self):
        """A 0.5x host decodes every other step (skips accounted), takes
        measurably longer, and no token of any stream changes — speed is
        execution latency, never content."""
        base = self._run()
        slow = self._run(host_speed=(0.5, 1.0))
        naive = self._run(host_speed=(0.5, 1.0), speed_aware=False)
        assert _streams(slow) == _streams(base) == _streams(naive)
        assert slow.steps > base.steps
        assert slow.counters()["host_skipped_steps"][0] > 0
        assert slow.counters()["host_skipped_steps"][1] == 0
        # per-host effective throughput surfaces the straggler
        tp = slow.counters()["host_throughput"]
        assert tp[0] < tp[1]


class TestGangSplit:
    def _engine(self, hbm_budget=4.0, **kw):
        return ServingEngine(None, None, n_slots=16,
                             backend=StubModelBackend(), mode="runtime",
                             hbm_budget=hbm_budget, kv_bytes=1.0,
                             depth_skew=99, **kw)

    def _submit(self, eng):
        rng = np.random.default_rng(0)
        n = 0
        for _ in range(4):                   # residents fill page0
            eng.submit(rng.integers(1, 250, 8), 24, home="page0")
            n += 1
        for _ in range(6):                   # oversized gang, same home
            eng.submit(rng.integers(1, 250, 8), 10, gang="big",
                       home="page0")
            n += 1
        return n

    def test_split_rehomes_overflow_and_preserves_streams(self):
        split = self._engine(cost_model=SERVE_COST, gang_split=True)
        park = self._engine(cost_model=SERVE_COST, gang_split=False)
        ns, np_ = self._submit(split), self._submit(park)
        split.run(max_steps=4000), park.run(max_steps=4000)
        assert len(split.completed) == ns and len(park.completed) == np_
        assert _streams(split) == _streams(park)
        c = split.counters()
        assert c["gang_splits"] == 1
        assert c["gang_split_members"] == 6       # none fit the full home
        assert park.counters()["gang_splits"] == 0
        assert split.steps < park.steps           # the split paid off
        for eng in (split, park):                 # ledger never overdrawn
            assert all(0.0 <= u <= eng.hbm_budget + 1e-9
                       for u in eng.hbm_used), eng.hbm_used

    def test_quote_parks_when_waiting_is_cheaper(self):
        """Pricey page crossings + residents about to finish: the wait
        quote undercuts the split quote and the gang parks (no split
        booked), yet still completes."""
        pricey = dataclasses.replace(SERVE_COST,
                                     level_table=(("page", 50.0),))
        eng = self._engine(hbm_budget=8.0, cost_model=pricey,
                           gang_split=True)
        rng = np.random.default_rng(0)
        n = 0
        for _ in range(4):                   # residents done in 3 steps
            eng.submit(rng.integers(1, 250, 8), 3, home="page0")
            n += 1
        for _ in range(5):                   # deficit 1: one member over
            eng.submit(rng.integers(1, 250, 8), 8, gang="big",
                       home="page0")
            n += 1
        eng.run(max_steps=4000)
        assert len(eng.completed) == n
        assert eng.counters()["gang_splits"] == 0


# ---------------------------------------------------------------------------
# S2: the serving cost tables cover every slots_topology level
# ---------------------------------------------------------------------------

class TestLevelCoverage:
    @pytest.mark.parametrize("pods", [1, 2, 3, 4])
    @pytest.mark.parametrize("hosts", [1, 2, 3, 4])
    def test_every_level_tabled_or_deliberately_free(self, pods, hosts):
        """No topology a ``slots_topology`` fleet can build may contain a
        level the serving cost models neither price in their table nor
        list as deliberately free — a new level must be priced on
        purpose, not silently at zero."""
        topo = slots_topology(4 * pods * hosts, hosts=hosts, pods=pods)
        for model in (SERVE_COST, BW_SERVE_COST):
            tabled = {entry[0] for entry in model.level_table}
            for name in topo.level_names():
                assert name in tabled or name in SERVE_FREE_LEVELS, \
                    (name, pods, hosts, model.level_table)

    def test_tables_price_host_and_pod(self):
        for model in (SERVE_COST, BW_SERVE_COST):
            tabled = {entry[0] for entry in model.level_table}
            assert {"host", "pod"} <= tabled
        # the bandwidth table is the flat table plus per-byte rates only
        assert [(e[0], e[1]) for e in BW_SERVE_COST.level_table] == \
            [(e[0], e[1]) for e in SERVE_COST.level_table]
        assert all(len(e) > 2 and e[2] > 0
                   for e in BW_SERVE_COST.level_table)


# ---------------------------------------------------------------------------
# S1: the paged backend's pool is sized by the HBM byte ledger
# ---------------------------------------------------------------------------

class TestHbmSizedPool:
    def test_page_bytes_formula(self):
        from repro.configs import get_config
        from repro.models import lm, paged
        import jax.numpy as jnp
        cfg = get_config("yi-6b").reduced(vocab=97)
        got = paged.kv_page_bytes(cfg, 16)
        n_attn = sum(reps * sum(1 for k in pat if k == "attn")
                     for pat, reps in lm._stages(cfg))
        assert n_attn > 0
        assert got == 2 * n_attn * 16 * cfg.n_kv_heads * cfg.hd * \
            jnp.dtype(cfg.cdtype).itemsize

    def test_pool_capacity_is_budget_over_page_bytes(self):
        """capacity == hbm_bytes // page_bytes exactly: a budget of
        k * page_bytes + remainder buys k usable pages (the trash page
        rides on top, unbudgeted)."""
        import jax
        from repro.configs import get_config
        from repro.models import api
        from repro.serving import PagedJaxModelBackend
        cfg = get_config("yi-6b").reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        pb = PagedJaxModelBackend(cfg, params, 32, page_size=8)
        budget = 7 * pb.page_bytes + pb.page_bytes // 2
        ledger = PagedJaxModelBackend(cfg, params, 32, page_size=8,
                                      hbm_bytes=budget)
        shard, _ = ledger.init(2)
        assert len(shard.free) == budget // ledger.page_bytes == 7
        assert shard.table.shape == (2, 32 // 8)
        # no budget: the historical slack heuristic, untouched
        shard2, _ = pb.init(2)
        assert len(shard2.free) == (2 + 2) * (32 // 8)
        # a budget too small for one page is a hard error, not a 0-pool
        with pytest.raises(AssertionError):
            PagedJaxModelBackend(cfg, params, 32, page_size=8,
                                 hbm_bytes=3).init(2)
