"""A tiny, dependency-free stand-in for the slice of `hypothesis` we use.

Tier-1 must collect and pass in a clean environment; ``hypothesis`` is an
optional extra.  When it is absent, ``tests/test_scheduler.py`` falls back
to this shim, which implements just enough of the API surface —
``given``/``settings`` decorators and the ``integers``/``floats``/
``booleans``/``composite`` strategies — to run the same property tests as
deterministic, seeded random sampling (seed = example index, so failures
reproduce exactly and runs are stable across machines).

This is *not* hypothesis: no shrinking, no example database, no coverage-
guided generation.  It trades those for zero dependencies and perfect
determinism, which is what a tier-1 gate needs.
"""

from __future__ import annotations

import functools
import random
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, fn: Callable[[random.Random], Any]):
        self._fn = fn

    def example(self, rng: random.Random) -> Any:
        return self._fn(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(
        lambda rng: min_value + (max_value - min_value) * rng.random())


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def composite(fn: Callable) -> Callable[..., Strategy]:
    """``@composite`` turns ``fn(draw, *args)`` into a strategy factory,
    exactly like hypothesis' decorator of the same name."""

    @functools.wraps(fn)
    def factory(*args, **kwargs) -> Strategy:
        def build(rng: random.Random) -> Any:
            def draw(strategy: Strategy) -> Any:
                return strategy.example(rng)

            return fn(draw, *args, **kwargs)

        return Strategy(build)

    return factory


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording the example budget (deadline etc. are ignored)."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategies: Strategy):
    """Run the test once per seeded example; the failing seed is reported."""

    def deco(fn):
        # NOTE: no functools.wraps — pytest follows ``__wrapped__`` to the
        # original signature and would mistake the drawn params for fixtures.
        # *args passes through ``self`` when the test is a method.
        def wrapper(*args):
            n = getattr(wrapper, "_shim_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(i)
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn)
                except Exception as e:  # annotate with the reproducing seed
                    raise AssertionError(
                        f"shim example #{i} (seed={i}) failed: {e!r}\n"
                        f"drawn={drawn}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


class _StrategiesModule:
    """Duck-type of ``hypothesis.strategies`` for ``import ... as st``."""

    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    composite = staticmethod(composite)


strategies = _StrategiesModule()
