"""Serving-engine tests on the stub model backend — no jax, no jit.

The engine is the second client of the shared SchedulerRuntime (the
discrete simulator is the first): decode slots are the runtime's cpus, KV
page groups are the hierarchy's affinity level, a gang's KV state is its
data object.  These tests drive the whole scheduler stack (gang
co-scheduling, SLA priority ordering, steal-driven admission, next-touch
KV re-homing, queue-depth-triggered rebalance, regeneration) against the
deterministic :class:`StubModelBackend`, whose output is a hash of each
request's full token history — any KV mishandling (lost splice, stale
slot, wrong-slot write) changes the stream and fails an equality assert.
"""

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.scheduler import StealCostModel
from repro.serving import (SERVE_COST, ServingEngine, StubModelBackend,
                           slots_topology)


def make_engine(n_slots=8, mode="runtime", **kw):
    return ServingEngine(None, None, n_slots=n_slots,
                         backend=StubModelBackend(), mode=mode, **kw)


def submit_all(eng, spec, seed=0, new_tokens=10, prompt_len=8):
    """spec: list of (gang, count, prio); returns submitted count."""
    rng = np.random.default_rng(seed)
    n = 0
    for gang, count, prio in spec:
        for _ in range(count):
            eng.submit(rng.integers(1, 200, prompt_len), new_tokens,
                       prio=prio, gang=gang)
            n += 1
    return n


def streams(eng):
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


# ---------------------------------------------------------------------------
# slots_topology: every slot is schedulable, whatever the remainder
# ---------------------------------------------------------------------------

class TestSlotsTopology:
    @settings(max_examples=40)
    @given(n_slots=st.integers(min_value=1, max_value=32),
           group=st.integers(min_value=1, max_value=8))
    def test_every_slot_is_a_leaf(self, n_slots, group):
        """The old ``n_slots // group`` derivation dropped the remainder
        (9 slots, group 4 -> 8 leaves; slot 8 unschedulable forever)."""
        topo = slots_topology(n_slots, group)
        assert topo.n_cpus == n_slots
        sizes = [len(p.children) for p in topo.components("page")]
        assert sum(sizes) == n_slots
        assert max(sizes) - min(sizes) <= 1      # remainder spread evenly
        assert min(sizes) >= 1                   # no empty page group

    def test_divisible_layout_unchanged(self):
        topo = slots_topology(8, 4)
        assert [len(p.children) for p in topo.components("page")] == [4, 4]

    def test_nine_by_four_regression(self):
        topo = slots_topology(9, 4)
        assert topo.n_cpus == 9
        # an engine over 9 slots must actually decode in all 9
        eng = make_engine(n_slots=9)
        n = submit_all(eng, [(None, 12, 0)], new_tokens=4)
        eng.run(max_steps=200)
        assert len(eng.completed) == n
        # with 12 requests of 4 tokens on 9 slots, the run needs only two
        # admission waves if every slot admits; a dropped slot forces a
        # third wave and noticeably more steps
        assert eng.steps <= 10, eng.steps


# ---------------------------------------------------------------------------
# gang co-scheduling + SLA priorities
# ---------------------------------------------------------------------------

class TestGangsAndPriorities:
    def test_gang_members_coscheduled_same_page(self):
        """A page-burst gang's first wave lands inside one page group —
        the shared-prefix KV affinity."""
        eng = make_engine(n_slots=8)
        submit_all(eng, [("g", 4, 0)])
        eng.step()
        slots = [s for s, r in enumerate(eng.slot_req) if r is not None]
        assert len(slots) == 4
        pages = {eng.topo.cpus[s].parent.index for s in slots}
        assert len(pages) == 1

    def test_sla_priority_orders_completions(self):
        """Higher-priority requests finish first when slots are scarce."""
        eng = make_engine(n_slots=4)
        submit_all(eng, [(None, 4, 0), (None, 4, 2)], new_tokens=6)
        eng.run(max_steps=200)
        prios = [r.prio for r in eng.completed]
        assert prios[:4] == [2, 2, 2, 2]
        assert prios[4:] == [0, 0, 0, 0]

    def test_late_submit_to_expanded_gang_is_scheduled(self):
        """Regression: a rebalance can *expand* a regenerated (closed,
        over-wide) gang bubble, dealing its members out individually and
        leaving the bubble object on no queue.  A later submit to that
        gang saw it 'scheduled' (members queued), inserted the new thread
        into the off-queue bubble, and nothing ever burst it — the
        request silently never decoded."""
        eng = make_engine(n_slots=8)
        n = submit_all(eng, [("fat", 16, 0), ("a", 2, 2)], new_tokens=12)
        for _ in range(3):
            eng.step()
        assert eng.regenerate_gang("fat") > 0     # closed 16-wide bubble
        guard = 0
        while eng.stats.rebalances == 0 and guard < 200:
            eng.step()
            guard += 1
        assert eng.stats.rebalances > 0, "rebalance never expanded the gang"
        rid = eng.submit(np.arange(1, 9, dtype=np.int32), 4, gang="fat")
        eng.run(max_steps=2000)
        assert sorted(r.rid for r in eng.completed) == list(range(n + 1))
        assert rid in {r.rid for r in eng.completed}

    def test_resubmit_to_finished_gang_is_scheduled(self):
        """Regression: the old sticky ``_woken`` flag meant a gang that
        completed (bubble dropped from the queues) could never be woken
        again — later submits to the same gang name were lost."""
        eng = make_engine(n_slots=4)
        submit_all(eng, [("g", 2, 0)], new_tokens=4)
        eng.run(max_steps=100)
        assert len(eng.completed) == 2
        submit_all(eng, [("g", 2, 1)], new_tokens=4, seed=1)
        eng.run(max_steps=100)
        assert len(eng.completed) == 4

    def test_admit_skips_husks_same_step(self):
        """Regression: a stale thread at the head of the queue (a
        finished gang's husk — ``remaining == 0`` / ``request.done``) made
        ``_admit`` release it and bail, idling the slot a whole engine
        step even with live work queued right behind.  The acquire loop
        must drop any number of husks and still admit the live request in
        the SAME wave.  One slot, so no other slot can mask the bug."""
        eng = make_engine(n_slots=1, group=1)
        rids = [eng.submit(np.arange(1, 9, dtype=np.int32), 4)
                for _ in range(3)]
        # forge husks: the two queue-head requests died before admission
        for q in eng.sched.queues.queues.values():
            for t in q.tasks:
                if t.request.rid in rids[:2]:
                    t.remaining = 0.0
                    t.request.done = True
        eng.step()
        assert eng.slot_req[0] is not None, "slot idled on a husk"
        assert eng.slot_req[0].rid == rids[2]
        # and the husks are gone, not wedged on a queue forever
        eng.run(max_steps=50)
        assert eng._drained()

    def test_late_joiner_honors_home(self):
        """Regression: ``submit(home=...)`` for a late joiner to an
        already-burst gang silently dropped ``home`` — the thread landed
        on the gang's burst list even when the caller routed it to
        another shard.  The caller's ``home`` must win."""
        eng = make_engine(n_slots=16, hosts=2)
        submit_all(eng, [("g", 4, 0)], new_tokens=12)
        eng.step()                      # the gang bursts on host0's side
        g = eng._gangs["gang:g"]
        assert g.burst, "precondition: gang must have burst"
        rid = eng.submit(np.arange(1, 9, dtype=np.int32), 12, gang="g",
                         home="host1")
        host1_q = eng._home_queue("host1")
        assert any(getattr(t, "request", None) is not None
                   and t.request.rid == rid for t in host1_q.tasks), \
            "late joiner's home was dropped"
        eng.run(max_steps=500)
        assert sorted(r.rid for r in eng.completed) == list(range(5))


# ---------------------------------------------------------------------------
# steal-driven admission
# ---------------------------------------------------------------------------

SKEW = [("fat", 16, 0), ("a", 2, 2), (None, 2, 1)]


class TestStealAdmission:
    def test_starving_slots_steal_from_loaded_page(self):
        eng = make_engine(mode="runtime")
        n = submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert len(eng.completed) == n
        s = eng.sched.stats
        assert s.steals > 0
        assert eng.runtime.data_migrations > 0     # next-touch re-homed KV

    def test_runtime_beats_admission_only(self):
        """The tentpole acceptance behaviour at test scale: same request
        set, measurably fewer engine steps."""
        a = make_engine(mode="admission")
        n = submit_all(a, SKEW)
        a.run(max_steps=1000)
        b = make_engine(mode="runtime")
        submit_all(b, SKEW)
        b.run(max_steps=1000)
        assert len(a.completed) == len(b.completed) == n
        assert b.steps * 1.2 <= a.steps
        # and scheduling never changes what was decoded
        assert streams(a) == streams(b)

    def test_admission_mode_never_steals(self):
        eng = make_engine(mode="admission")
        submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert eng.sched.stats.steals == 0
        assert eng.runtime.data_migrations == 0

    def test_steal_cost_billed_as_admission_latency(self):
        eng = make_engine(mode="runtime")
        submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert eng.stats.stall_steps > 0
        assert eng.stats.stall_steps == pytest.approx(
            eng.sched.stats.steal_cost + eng.sched.stats.rebalance_cost)


# ---------------------------------------------------------------------------
# KV next-touch re-homing (park + batched splice)
# ---------------------------------------------------------------------------

class TestKVNextTouch:
    def test_regenerate_then_resubmit_resumes_continuation(self):
        """Regression for the stale-slot bug: the old engine popped the
        thread into an unused local, left the freed slot's token behind,
        and re-prefilled on re-admission — the resumed gang decoded from
        stale state.  Parked KV + the batched splice must make an
        interrupted run's streams identical to an uninterrupted one."""
        def run(interrupt):
            eng = make_engine(n_slots=8)
            n = submit_all(eng, [("g", 4, 0), (None, 2, 1)], new_tokens=12)
            if interrupt:
                for _ in range(4):
                    eng.step()
                assert eng.regenerate_gang("g") > 0
            eng.run(max_steps=500)
            assert len(eng.completed) == n
            return streams(eng), eng

        base, _ = run(False)
        intr, eng = run(True)
        assert base == intr
        assert eng.stats.kv_parks > 0
        assert eng.stats.prefills == 6      # no request prefilled twice

    def test_freed_slot_does_not_decode_stale_token(self):
        eng = make_engine(n_slots=4)
        submit_all(eng, [("g", 4, 0)], new_tokens=8)
        for _ in range(3):
            eng.step()
        eng.regenerate_gang("g")
        assert all(int(t) == 0 for t in eng.tokens.ravel())

    def test_migrated_gang_rehomes_kv_across_pages(self):
        """A gang stolen across page groups re-homes its KV on the first
        post-migration admission: data_migrations fires and at least one
        re-home crosses page groups."""
        eng = make_engine(mode="runtime")
        n = submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert len(eng.completed) == n
        assert eng.stats.kv_migrations == eng.runtime.data_migrations > 0
        assert eng.stats.kv_page_moves > 0

    def test_splices_are_batched(self):
        """One splice op per admission wave, not one per request."""
        eng = make_engine(n_slots=8)
        submit_all(eng, [(None, 8, 0)])
        eng.step()
        assert eng.stats.kv_spliced_slots == 8
        assert eng.stats.kv_splices == 1

    def test_regenerate_while_member_pending_does_not_duplicate(self):
        """A gang member claimed by a steal but still waiting out its
        admission stall (``_pending``) must fold back into the regenerated
        bubble — leaving it pending too would schedule it twice."""
        eng = make_engine(mode="runtime")
        n = submit_all(eng, SKEW)
        guard = 0
        while not eng._pending and guard < 200:
            eng.step()
            guard += 1
        assert eng._pending, "workload never produced a pending admission"
        gangs = {t.parent.name for t in eng._pending.values()
                 if t.parent is not None}
        assert "gang:fat" in gangs
        eng.regenerate_gang("fat")
        assert not any(t.parent is not None and t.parent.name == "gang:fat"
                       for t in eng._pending.values())
        eng.run(max_steps=2000)
        rids = sorted(r.rid for r in eng.completed)
        assert rids == list(range(n))            # all, exactly once
        # and the interruption never changed what was decoded
        ref = make_engine(mode="admission")
        submit_all(ref, SKEW)
        ref.run(max_steps=2000)
        assert streams(ref) == streams(eng)


# ---------------------------------------------------------------------------
# wave-batched prefill: one model call per (host, length) per wave
# ---------------------------------------------------------------------------

class TestWavePrefill:
    def test_one_call_per_wave_not_per_request(self):
        """8 same-length prompts admitted in one wave prefill in ONE
        backend call; the per-request ledger still counts all 8."""
        eng = make_engine(n_slots=8)
        submit_all(eng, [(None, 8, 0)])
        eng.step()
        assert eng.stats.prefills == 8        # requests prefilled
        assert eng.stats.prefill_waves == 1   # backend calls issued

    def test_mixed_lengths_split_waves(self):
        """A wave mixes prompt lengths: one call per distinct length (the
        backend stacks same-shape prompts only)."""
        eng = make_engine(n_slots=8)
        rng = np.random.default_rng(0)
        for i in range(8):
            eng.submit(rng.integers(1, 200, 6 + (i % 2)), 4)
        eng.step()
        assert eng.stats.prefills == 8
        assert eng.stats.prefill_waves == 2

    def test_wave_prefill_streams_equal_per_request_loop(self):
        """Batching the prefill must never change a stream or a step."""
        spec = [("g", 4, 0), (None, 3, 1), ("h", 2, 2)]

        def run(wave):
            eng = make_engine(n_slots=8, wave_prefill=wave)
            n = submit_all(eng, spec, new_tokens=8)
            eng.run(max_steps=500)
            assert len(eng.completed) == n
            return eng.steps, streams(eng), eng

        steps_w, st_w, eng_w = run(True)
        steps_l, st_l, eng_l = run(False)
        assert (steps_w, st_w) == (steps_l, st_l)
        assert eng_w.stats.prefills == eng_l.stats.prefills == 9
        assert eng_w.stats.prefill_waves < eng_w.stats.prefills
        assert eng_l.stats.prefill_waves == 0    # loop mode: no wave calls

    def test_stub_wave_matches_scalar_prefill(self):
        """The vectorised stub fold is exact, not approximately equal."""
        backend = StubModelBackend()
        rng = np.random.default_rng(7)
        prompts = [rng.integers(1, 250, 11) for _ in range(6)]
        wave = backend.prefill_wave(prompts)
        for prompt, (tok, state) in zip(prompts, wave):
            stok, sstate = backend.prefill(prompt)
            assert tok == stok
            assert (state == sstate).all()


# ---------------------------------------------------------------------------
# queue-depth-triggered rebalance
# ---------------------------------------------------------------------------

class TestQueueDepthRebalance:
    def test_depth_skew_triggers_rebalance(self):
        eng = make_engine(mode="runtime")
        n = submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert len(eng.completed) == n
        assert eng.stats.rebalances > 0
        assert eng.sched.stats.rebalance_moves > 0

    def test_zero_cost_model_never_rebalances(self):
        """The cost-benefit gate: free stealing means a re-spread can
        never pay for itself (same degradation as AdaptivePolicy under
        ZERO_COST)."""
        eng = make_engine(mode="runtime", cost_model=StealCostModel())
        n = submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert len(eng.completed) == n
        assert eng.stats.rebalances == 0
        assert eng.sched.stats.steals > 0       # still stealing, for free

    def test_rebalance_disabled_in_admission_mode(self):
        eng = make_engine(mode="admission")
        submit_all(eng, SKEW)
        eng.run(max_steps=1000)
        assert eng.stats.rebalances == 0


# ---------------------------------------------------------------------------
# conservation: whatever the scheduling traffic, every request completes
# exactly once with exactly the asked-for tokens
# ---------------------------------------------------------------------------

class TestConservation:
    @settings(max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_random_workloads_complete_exactly(self, seed):
        rng = np.random.default_rng(seed)
        eng = make_engine(n_slots=int(rng.integers(2, 12)))
        spec = []
        for g in range(int(rng.integers(1, 5))):
            spec.append((f"g{g}" if rng.random() < 0.7 else None,
                         int(rng.integers(1, 7)), int(rng.integers(0, 3))))
        n = submit_all(eng, spec, seed=seed,
                       new_tokens=int(rng.integers(2, 9)))
        eng.run(max_steps=4000)
        rids = sorted(r.rid for r in eng.completed)
        assert rids == list(range(n))            # all, exactly once
        for r in eng.completed:
            assert len(r.out_tokens) == r.max_new_tokens
