"""Open-loop workload + SLA-tier scheduling tests (stub model, no jax).

The workload layer (``repro.serving.workload``) is the arrival side:
deterministic seeded traces (Poisson / bursty / diurnal), heavy-tailed
length mixes, SLA classes.  The engine side under test is everything PR 6
grew: the WDRR admission gate riding the covering-list walk as a task
filter, multilevel-feedback demotion, KV park/splice preemption, and the
per-request latency ledger (TTFT / inter-token gaps / goodput-under-SLA).

The load-bearing invariant throughout: scheduling — priorities, WDRR,
demotion, preemption, parking — may change *when* a token decodes, never
*what* is decoded.  Streams are asserted equal across engines and
admission orders on every property run.
"""

import math

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.bubble import reset_ids
from repro.serving import (SLA_CLASSES, ServingEngine, StubModelBackend,
                           bursty_arrivals, diurnal_arrivals, drive,
                           goodput_under_sla, make_trace, percentile,
                           poisson_arrivals)


def make_engine(n_slots=8, **kw):
    reset_ids()
    return ServingEngine(None, None, n_slots=n_slots,
                         backend=StubModelBackend(), **kw)


def streams(eng):
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


# ---------------------------------------------------------------------------
# the workload layer itself
# ---------------------------------------------------------------------------

class TestTraces:
    def test_trace_deterministic_under_seed(self):
        a = make_trace(steps=60, rate=1.3, seed=7)
        b = make_trace(steps=60, rate=1.3, seed=7)
        assert len(a) == len(b)
        for ra, rb in zip(a, b):
            assert (ra.step, ra.sla, ra.new_tokens, ra.gang) == \
                (rb.step, rb.sla, rb.new_tokens, rb.gang)
            assert np.array_equal(ra.prompt, rb.prompt)

    def test_seeds_differ(self):
        a = make_trace(steps=60, rate=1.3, seed=0)
        b = make_trace(steps=60, rate=1.3, seed=1)
        assert [(r.step, r.sla, r.new_tokens) for r in a] != \
            [(r.step, r.sla, r.new_tokens) for r in b]

    def test_every_class_arrives_with_submit_steps(self):
        trace = make_trace(steps=120, rate=1.5, seed=0)
        classes = {r.sla for r in trace}
        assert classes == {"interactive", "standard", "batch"}
        assert all(0 <= r.step < 120 for r in trace)
        assert all(r.new_tokens >= 1 and len(r.prompt) >= 1 for r in trace)
        # batch arrives as gangs; the other tiers ride solo
        assert all((r.gang is not None) == (r.sla == "batch")
                   for r in trace)

    def test_arrival_processes_shapes(self):
        rng = np.random.default_rng(0)
        for counts in (poisson_arrivals(1.5, 64, rng),
                       bursty_arrivals(3.0, 0.2, 8, 8, 64, rng),
                       diurnal_arrivals(1.5, 1.0, 16, 64, rng)):
            assert len(counts) == 64
            assert all(isinstance(c, int) and c >= 0 for c in counts)

    def test_bursty_and_diurnal_traces_drain(self):
        for process in ("bursty", "diurnal"):
            trace = make_trace(steps=48, rate=1.2, seed=2, process=process)
            eng = drive(make_engine(sla_classes=SLA_CLASSES, preempt=True),
                        trace, max_steps=20000)
            assert len(eng.completed) == len(trace)

    def test_percentile_nearest_rank(self):
        assert percentile([], 99) == 0.0
        assert percentile([5], 50) == 5.0
        xs = list(range(1, 101))          # 1..100
        assert percentile(xs, 50) == 50.0
        assert percentile(xs, 99) == 99.0
        assert percentile(xs, 100) == 100.0


# ---------------------------------------------------------------------------
# the latency ledger
# ---------------------------------------------------------------------------

class TestLatencyLedger:
    def test_ttft_stamped_at_actual_admission(self):
        """8 same-class requests onto 4 slots: the second wave's TTFT is
        the queueing delay, stamped when prefill actually ran."""
        eng = make_engine(n_slots=4)
        for _ in range(8):
            eng.submit(np.arange(1, 7, dtype=np.int32), 4, sla="standard")
        eng.run(max_steps=100)
        ttfts = sorted(r.first_token_step - r.submit_step
                       for r in eng.completed)
        assert ttfts[:4] == [0, 0, 0, 0]
        assert all(t > 0 for t in ttfts[4:])
        summary = eng.latency_summary()
        assert summary["classes"]["standard"]["n"] == 8
        assert summary["classes"]["standard"]["ttft_p50"] == 0.0
        assert summary["classes"]["standard"]["ttft_p99"] == ttfts[-1]

    def test_inter_token_gaps_counted(self):
        eng = make_engine(n_slots=2)
        eng.submit(np.arange(1, 7, dtype=np.int32), 5, sla="interactive")
        eng.run(max_steps=50)
        gaps = eng._gaps["interactive"]
        assert len(gaps) == 4             # 5 tokens = prefill + 4 decodes
        # prefill and the first decode share an engine step (gap 0);
        # uncontended decode then yields one token per step
        assert gaps == [0, 1, 1, 1]

    def test_goodput_judged_on_contract_class(self):
        """A late interactive completion is not 'good'; batch is good on
        completion alone (no TTFT SLO)."""
        eng = make_engine(n_slots=1, group=1)
        slo = SLA_CLASSES["interactive"].ttft_slo
        eng.submit(np.arange(1, 7, dtype=np.int32), slo + 4, sla="batch")
        eng.submit(np.arange(1, 9, dtype=np.int32), 2, sla="interactive")
        eng.run(max_steps=100)
        good, total = goodput_under_sla(eng.completed)
        assert total == 2
        assert good == 1                  # interactive blew its SLO; batch ok


# ---------------------------------------------------------------------------
# WDRR admission + demotion + preemption
# ---------------------------------------------------------------------------

class TestSLAScheduling:
    def test_wdrr_keeps_batch_flowing_under_interactive_load(self):
        """Pure priorities would starve batch until the interactive queue
        empties; the deficit round-robin must admit batch work while
        interactive backlog still exists."""
        eng = make_engine(n_slots=4, sla_classes=SLA_CLASSES)
        for _ in range(12):
            eng.submit(np.arange(1, 7, dtype=np.int32), 6, sla="interactive")
        for _ in range(4):
            eng.submit(np.arange(1, 5, dtype=np.int32), 6, sla="batch")
        eng.run(max_steps=400)
        assert len(eng.completed) == 16
        first_batch = min(r.first_token_step for r in eng.completed
                          if r.sla == "batch")
        last_interactive = max(r.first_token_step for r in eng.completed
                               if r.sla == "interactive")
        assert first_batch < last_interactive, \
            "WDRR never admitted batch under interactive backlog"

    def test_priority_only_engine_starves_batch_longer(self):
        """The same load on an SLA-less engine with raw priorities admits
        every interactive request first — the contrast that proves the
        WDRR gate is doing the arbitration."""
        def first_batch_admission(sla_classes):
            eng = make_engine(n_slots=4, sla_classes=sla_classes)
            for _ in range(12):
                eng.submit(np.arange(1, 7, dtype=np.int32), 6,
                           prio=2, sla="interactive")
            for _ in range(4):
                eng.submit(np.arange(1, 5, dtype=np.int32), 6,
                           prio=0, sla="batch")
            eng.run(max_steps=400)
            return min(r.first_token_step for r in eng.completed
                       if r.sla == "batch")

        assert first_batch_admission(SLA_CLASSES) < \
            first_batch_admission(None)

    def test_long_runner_demotes_but_keeps_contract(self):
        cls = SLA_CLASSES["interactive"]
        eng = make_engine(n_slots=2, sla_classes=SLA_CLASSES)
        rid = eng.submit(np.arange(1, 7, dtype=np.int32),
                         cls.demote_after + 8, sla="interactive")
        eng.run(max_steps=200)
        req = eng._reqs[rid]
        assert eng.stats.demotions >= 1
        assert req.tier == cls.demote_to          # scheduled as standard...
        assert req.sla == "interactive"           # ...judged as interactive

    def test_preemption_parks_batch_for_interactive(self):
        """Slots full of a batch gang, an interactive arrival: the gang's
        KV parks (park/splice path), the interactive request admits, and
        the resumed gang decodes its exact continuation (streams equal to
        an unpreempted run)."""
        def run(preempt):
            eng = make_engine(n_slots=4, sla_classes=SLA_CLASSES,
                              preempt=preempt, preempt_cooldown=2)
            rng = np.random.default_rng(0)
            for _ in range(4):
                eng.submit(rng.integers(1, 200, 6), 24, sla="batch",
                           gang="bg")
            for _ in range(3):
                eng.step()
            rid = eng.submit(rng.integers(1, 200, 6), 4, sla="interactive")
            eng.run(max_steps=400)
            assert len(eng.completed) == 5
            return eng, rid

        pre, rid = run(True)
        base, _ = run(False)
        assert pre.stats.preemptions >= 1 and pre.stats.preempt_parks >= 1
        assert streams(pre) == streams(base), \
            "preemption changed a decoded stream"
        # the interactive request got in measurably earlier
        ttft = {e: next(r.first_token_step - r.submit_step
                        for r in eng.completed if r.rid == rid)
                for e, (eng, rid) in (("pre", (pre, rid)),
                                      ("base", (base, rid)))}
        assert ttft["pre"] < ttft["base"]

    def test_same_class_streams_order_invariant(self):
        """Same-class arrivals submitted in opposite per-step order decode
        identical streams (matched by prompt — rids differ)."""
        trace = [r for r in make_trace(steps=40, rate=1.5, seed=3)
                 if r.sla == "standard"]
        a = drive(make_engine(sla_classes=SLA_CLASSES), list(trace),
                  max_steps=20000)
        by_step: dict[int, list] = {}
        for r in trace:
            by_step.setdefault(r.step, []).append(r)
        flipped = [r for s in sorted(by_step) for r in reversed(by_step[s])]
        b = drive(make_engine(sla_classes=SLA_CLASSES), flipped,
                  max_steps=20000)
        sa = sorted((tuple(r.prompt), tuple(r.out_tokens))
                    for r in a.completed)
        sb = sorted((tuple(r.prompt), tuple(r.out_tokens))
                    for r in b.completed)
        assert sa == sb


# ---------------------------------------------------------------------------
# the open-loop no-starvation property (satellite 4)
# ---------------------------------------------------------------------------

class TestOpenLoopNoStarvation:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           rate=st.floats(min_value=0.8, max_value=2.2))
    def test_everyone_completes_no_class_unbounded(self, seed, rate):
        """Sustained Poisson load, all three SLA classes, WDRR + demotion
        + preemption on: every request completes, every class's p99 TTFT
        is bounded by the run itself, preempted batch gangs resume via
        splice with exact streams (equal to the FIFO engine's, which
        never preempts), and the ledger accounts every completion."""
        trace = make_trace(steps=48, rate=rate, seed=seed)
        if not trace:
            return
        sla = drive(make_engine(sla_classes=SLA_CLASSES, preempt=True,
                                preempt_cooldown=4),
                    trace, max_steps=40000)
        fifo = drive(make_engine(mode="admission"), trace, max_steps=40000)
        # no starvation: every arrival completed, on both engines
        assert len(sla.completed) == len(trace) == len(fifo.completed)
        # exact streams across engines — including any parked-and-resumed
        # gang (the splice path restores the precise continuation)
        assert streams(sla) == streams(fifo)
        summary = sla.latency_summary()
        for name, row in summary["classes"].items():
            assert row["ttft_p99"] < sla.steps, (name, row)
            assert row["tok_p99"] < sla.steps, (name, row)
        assert summary["goodput"]["total"] == len(trace)
        # ledger sanity: stamps are ordered and complete
        for r in sla.completed:
            assert r.first_token_step is not None
            assert r.submit_step <= r.first_token_step
            assert r.first_token_step <= r.finish_step
