"""Strategy plumbing tests (plan-level; the compiles happen in the dry-run)."""

import jax

from repro.configs import get_config
from repro.launch import dryrun
from repro.launch.mesh import single_device_mesh


class TestStrategyParts:
    def test_base(self):
        assert dryrun.strategy_parts("bubbles") == ("bubbles", False, ())

    def test_sp(self):
        assert dryrun.strategy_parts("bubbles_sp") == ("bubbles", True, ())

    def test_fsdp(self):
        base, sp, st = dryrun.strategy_parts("fsdp_sp")
        assert base == "fsdp" and sp and st == ("model",)

    def test_bubbles_fsdp(self):
        base, sp, st = dryrun.strategy_parts("bubbles_fsdp_sp")
        assert base == "bubbles" and sp and st == ("data",)


class TestMakePlan:
    def test_fsdp_plan_no_tp(self):
        mesh = single_device_mesh(("data", "model"))
        cfg = get_config("yi-6b")
        p = dryrun.make_plan(cfg, "train_4k", mesh, "fsdp")
        assert p.axes_of("heads") is None
        assert p.axes_of("batch") == ("data",)

    def test_ep2d_plan(self):
        mesh = single_device_mesh(("data", "expert", "ffn"))
        cfg = get_config("grok-1-314b")
        p = dryrun.make_plan(cfg, "train_4k", mesh, "ep2d")
        assert p.axes_of("experts") == ("expert",)
        assert p.axes_of("heads") == ("expert", "ffn")

    def test_sp_cfg_threading(self):
        """_lower_compile sets sp_axis/batch_axes on the cfg (observable via
        a tiny lowering on the 1x1 mesh)."""
        mesh = single_device_mesh(("data", "model"))
        cfg = get_config("yi-6b").reduced(n_layers=1)
        import repro.models.api as api_mod
        old = dict(api_mod.SHAPES["train_4k"])
        api_mod.SHAPES["train_4k"] = dict(kind="train", seq=16, batch=2)
        try:
            compiled, plan, sh, args = dryrun._lower_compile(
                cfg, "train_4k", mesh, "bubbles_sp")
            assert compiled is not None
        finally:
            api_mod.SHAPES["train_4k"] = old


def test_model_flops_sane():
    cfg = get_config("yi-6b")
    t = dryrun.model_flops(cfg, "train_4k")
    # 6 * 6.06e9 * (256*4096) ≈ 3.8e16
    assert 3e16 < t < 5e16
    d = dryrun.model_flops(cfg, "decode_32k")
    # train/decode flop ratio = (6 tok_train) / (2 B_decode) ≈ 2.5e4
    assert d < t / 1e4
