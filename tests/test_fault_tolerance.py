"""Regression + property tests for the fault-tolerance seed.

Two seed bugs fixed in the elastic-fleet PR are pinned here:

* ``regenerate_straggler_bubbles`` cascaded: iterating (queue, parent)
  pairs bottom-up re-moved freshly-pushed tasks at every higher pair, so
  anything on a straggler's local queue shot straight to the global list
  (and was counted once per hop).  The paper's §3.3.3 regeneration move is
  exactly ONE level up — wide enough for healthy siblings to steal, narrow
  enough to keep affinity.

* ``FleetSpec.alive_shape`` subtracted every dead host's data column
  fleet-wide, as if a host loss in pod 0 destroyed the same column in
  every other pod.  The survivor mesh must instead be the largest
  fully-alive rectangle — dropping a badly-wounded pod entirely can keep
  far more of the fleet.
"""

import itertools

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core import BubbleScheduler, bubble, novascale_16, thread
from repro.distributed.fault_tolerance import (FleetSpec,
                                               regenerate_straggler_bubbles)


class TestStragglerRegeneration:
    def test_moves_exactly_one_level(self):
        """Seed regression: a task on the straggler's cpu queue must land on
        its NODE queue (one level up), not cascade to the global list; a
        task already on the node queue moves to the machine.  The seed
        cascaded both to global and returned moved == 3."""
        sched = BubbleScheduler(novascale_16())
        a, b = bubble(thread(5.0)), bubble(thread(5.0))
        cpu0 = sched.topo.cpus[0]
        node0 = sched.topo.components("node")[0]
        q_cpu0 = sched.queues.queue_of(cpu0)
        q_node0 = sched.queues.queue_of(node0)
        q_cpu0.push(a)
        q_node0.push(b)
        moved = regenerate_straggler_bubbles(sched, [0])
        assert moved == 2
        assert list(q_node0.tasks) == [a]
        assert list(sched.queues.global_queue().tasks) == [b]
        assert len(q_cpu0) == 0

    def test_shared_queues_drained_once(self):
        """Two stragglers under the same node share every queue above the
        cpu level; the shared queues must be planned once, so the count
        matches the number of distinct tasks moved."""
        sched = BubbleScheduler(novascale_16())
        node0 = sched.topo.components("node")[0]
        sched.queues.queue_of(node0).push(bubble(thread(2.0)))
        cpus = [leaf.cpu for leaf in node0.leaves()][:2]
        moved = regenerate_straggler_bubbles(sched, cpus)
        assert moved == 1
        assert len(sched.queues.global_queue()) == 1

    def test_empty_chain_is_noop(self):
        sched = BubbleScheduler(novascale_16())
        assert regenerate_straggler_bubbles(sched, [0, 1, 2]) == 0


def brute_best(spec: FleetSpec):
    """Largest fully-alive rectangle by exhaustive pod-subset search."""
    alive = [p for p in range(spec.pods) if p not in spec.dead_pods]
    dead_cols = {p: {d for q, d in spec.dead_hosts if q == p}
                 for p in alive}
    best = None
    for r in range(1, len(alive) + 1):
        for keep in itertools.combinations(alive, r):
            cols = spec.data - len(set().union(*(dead_cols[p] for p in keep)))
            if cols <= 0:
                continue
            key = (r * cols, r)
            if best is None or key > best[0]:
                best = (key, r, cols)
    return None if best is None else (best[1], best[2])


class TestAliveShape:
    def test_wounded_pod_dropped_not_projected(self):
        """Seed regression: three dead hosts in pod 0 must cost pod 0, not
        three data columns of every pod.  Seed answered (4, 1, 2) — 8
        devices; the largest survivor rectangle is (3, 4, 2) — 24."""
        spec = FleetSpec(pods=4, data=4, model=2,
                         dead_hosts=frozenset({(0, 0), (0, 1), (0, 2)}))
        assert spec.alive_shape() == (3, 4, 2)
        assert spec.alive_axes() == ("pod", "data", "model")

    def test_single_dead_host_keeps_column_choice(self):
        # one dead host: keeping the pod costs a column fleet-wide (2x3),
        # dropping the pod keeps all columns for the survivor (1x4) —
        # the rectangle 2x3 wins
        spec = FleetSpec(pods=2, data=4, model=2,
                         dead_hosts=frozenset({(0, 1)}))
        assert spec.alive_shape() == (2, 3, 2)

    def test_dead_host_in_dead_pod_ignored(self):
        spec = FleetSpec(pods=2, data=4, model=2,
                         dead_pods=frozenset({1}),
                         dead_hosts=frozenset({(1, 0), (1, 1), (1, 2)}))
        assert spec.alive_shape() == (4, 2)
        assert spec.alive_axes() == ("data", "model")

    def test_exhausted_raises(self):
        import pytest
        spec = FleetSpec(pods=1, data=2, model=1,
                         dead_hosts=frozenset({(0, 0), (0, 1)}))
        with pytest.raises(RuntimeError):
            spec.alive_shape()

    @settings(max_examples=60)
    @given(pods=st.integers(min_value=1, max_value=4),
           data=st.integers(min_value=1, max_value=4),
           kills=st.integers(min_value=0, max_value=6),
           seed=st.integers(min_value=0, max_value=999))
    def test_matches_bruteforce_rectangle(self, pods, data, kills, seed):
        import numpy as np
        rng = np.random.default_rng(seed)
        cells = [(p, d) for p in range(pods) for d in range(data)]
        idx = rng.permutation(len(cells))[:min(kills, len(cells))]
        dead = frozenset(cells[i] for i in idx)
        spec = FleetSpec(pods=pods, data=data, model=2, dead_hosts=dead)
        want = brute_best(spec)
        if want is None:
            import pytest
            with pytest.raises(RuntimeError):
                spec._survivor_grid()
        else:
            assert spec._survivor_grid() == want
