"""Property tests for the pod-sharded serving topology + HBM accounting.

Two families, both runnable under real ``hypothesis`` or the deterministic
``tests/_hypothesis_shim.py``:

* **topology** — over 1-4 pods x 1-4 hosts x ragged page/slot fanouts:
  slot conservation (every submitted slot is a schedulable leaf, no page
  group empty), ``levels_crossed`` symmetry between leaves, and
  steal-survey reachability (work parked on *any* slot's list can be
  stolen by *any* other slot, under both the free and the costed victim
  selection — a partitioned survey would starve whole shards);
* **HBM accounting** — random admit/park/steal/rebalance traffic against
  per-page-group budgets: the KV ledger never goes negative or above
  budget at any step, it always equals the sum of live slot reservations,
  and refused loot is always re-admitted somewhere (every request
  completes — no gang starves because a full group turned it away);
* **per-host execution determinism** — on every 1-4 pod x 1-4 host fleet,
  the host-sharded execution model (one ``decode_step`` per host batch,
  wave-batched prefill) produces bit-identical decode streams *and* step
  counts to the historical global batch: sharding execution is pure
  modeling, never scheduling.
"""

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.bubble import thread
from repro.core.policies import StealPolicy
from repro.core.scheduler import ZERO_COST, StealCostModel
from repro.serving import (SERVE_COST, ServingEngine, StubModelBackend,
                           slots_topology)


@st.composite
def fleet(draw):
    """(pods, hosts, group, n_slots) with ragged splits everywhere."""
    pods = draw(st.integers(min_value=1, max_value=4))
    hosts = draw(st.integers(min_value=1, max_value=4))
    group = draw(st.integers(min_value=1, max_value=5))
    n_hosts = pods * hosts
    n_slots = draw(st.integers(min_value=n_hosts, max_value=n_hosts * 9))
    return pods, hosts, group, n_slots


# ---------------------------------------------------------------------------
# topology: conservation, symmetry, reachability
# ---------------------------------------------------------------------------

class TestFleetTopology:
    @settings(max_examples=40)
    @given(cfg=fleet())
    def test_slot_conservation(self, cfg):
        pods, hosts, group, n_slots = cfg
        topo = slots_topology(n_slots, group, hosts=hosts, pods=pods)
        assert topo.n_cpus == n_slots
        pages = topo.components("page")
        sizes = [len(p.children) for p in pages]
        assert sum(sizes) == n_slots
        assert min(sizes) >= 1                      # no empty page group
        assert all(s <= group for s in sizes)       # group is a ceiling
        # every host owns at least one page and sizes stay near-even
        host_level = "host" if pods * hosts > 1 else "batch"
        by_host = {}
        for p in pages:
            anc = p
            while anc.level.name != host_level:
                anc = anc.parent
            by_host.setdefault(anc.index, 0)
            by_host[anc.index] += len(p.children)
        assert len(by_host) == max(pods * hosts, 1)
        assert max(by_host.values()) - min(by_host.values()) <= 1

    @settings(max_examples=25)
    @given(cfg=fleet(), a=st.integers(min_value=0, max_value=10 ** 6),
           b=st.integers(min_value=0, max_value=10 ** 6))
    def test_levels_crossed_symmetry(self, cfg, a, b):
        pods, hosts, group, n_slots = cfg
        topo = slots_topology(n_slots, group, hosts=hosts, pods=pods)
        ca, cb = topo.cpus[a % n_slots], topo.cpus[b % n_slots]
        assert topo.levels_crossed(ca.cpu, cb) == \
            topo.levels_crossed(cb.cpu, ca)
        # the boundary level both directions price is the same one
        assert topo.crossing_level(ca.cpu, cb) == \
            topo.crossing_level(cb.cpu, ca)
        if ca is cb:
            assert topo.levels_crossed(ca.cpu, cb) == 0
            assert topo.crossing_level(ca.cpu, cb) is None

    @settings(max_examples=15, deadline=None)
    @given(cfg=fleet(), costed=st.booleans())
    def test_every_slot_reachable_by_steal_survey(self, cfg, costed):
        """Work parked on any slot's own list must be stealable from any
        other slot: the survey walks every covering level, so no shard of
        the fleet is invisible to an idle slot anywhere else."""
        pods, hosts, group, n_slots = cfg
        topo = slots_topology(n_slots, group, hosts=hosts, pods=pods)
        cm = SERVE_COST if costed else ZERO_COST
        # pin src/dst spot checks to the fleet corners + a mid slot: the
        # far corner pair crosses every level the topology has
        srcs = {0, n_slots - 1, n_slots // 2}
        for src in srcs:
            for dst in srcs:
                if src == dst:
                    continue
                pol = StealPolicy(topo, cost_model=cm)
                t = thread(4.0, name="loot", data="loot")
                pol.sched.queues.covering(dst)[0].push(t)
                got = pol.next(src, 0.0)
                assert got is t, (src, dst, costed)
                assert got.stolen                    # flagged for next-touch
                assert pol.sched.stats.steals == 1


# ---------------------------------------------------------------------------
# per-host execution determinism: sharding the decode changes nothing
# ---------------------------------------------------------------------------

class TestPerHostDecodeDeterminism:
    """The tentpole invariant: per-host decode batches + wave-batched
    prefill are *execution* changes only.  On any fleet shape, with mixed
    gangs / priorities / cross-host homes / mid-run regeneration, the
    sharded engine must decode bit-identical streams in the exact same
    number of engine steps as the global-batch engine."""

    def _drive(self, cfg, seed, per_host, wave):
        pods, hosts, group, n_slots = cfg
        eng = ServingEngine(None, None, n_slots=n_slots, group=group,
                            hosts=hosts, pods=pods,
                            backend=StubModelBackend(),
                            per_host_decode=per_host, wave_prefill=wave)
        rng = np.random.default_rng(seed)
        hostnames = [c.name for c in eng.topo.components("host")] \
            if pods * hosts > 1 else [None]
        gangs, n = [], 0
        for g in range(int(rng.integers(2, 5))):
            gang = f"g{g}" if rng.random() < 0.7 else None
            if gang is not None:
                gangs.append(gang)
            home = hostnames[int(rng.integers(0, len(hostnames)))]
            for _ in range(int(rng.integers(1, 6))):
                eng.submit(rng.integers(1, 200, 6), int(rng.integers(2, 8)),
                           prio=int(rng.integers(0, 3)), gang=gang,
                           home=home)
                n += 1
        steps = 0
        while not eng._drained() and steps < 4000:
            eng.step()
            steps += 1
            if gangs and steps % 5 == 0:
                eng.regenerate_gang(gangs[(steps // 5) % len(gangs)])
        assert len(eng.completed) == n, (cfg, len(eng.completed), n)
        return (eng.steps, {r.rid: tuple(r.out_tokens)
                            for r in eng.completed}, eng)

    @settings(max_examples=10, deadline=None)
    @given(cfg=fleet(), seed=st.integers(min_value=0, max_value=10 ** 6))
    def test_per_host_streams_equal_global_batch(self, cfg, seed):
        steps_g, streams_g, _ = self._drive(cfg, seed, False, False)
        steps_h, streams_h, eng = self._drive(cfg, seed, True, True)
        assert steps_h == steps_g
        assert streams_h == streams_g
        # the sharded engine really ran one batch per host
        n_hosts = cfg[0] * cfg[1]
        assert len(eng._exec_groups) == (n_hosts if n_hosts > 1 else 1)
        # every decoded token is accounted to exactly one host batch
        # (each request's FIRST token comes from prefill, not decode)
        assert sum(eng.stats.host_active_slots) == \
            sum(len(s) for s in streams_h.values()) - eng.stats.prefills

    def test_idle_host_skips_decode(self):
        """A host whose batch is empty launches no decode_step: its
        per-host ledger stays behind the busy host's."""
        eng = ServingEngine(None, None, n_slots=8, hosts=2,
                            backend=StubModelBackend())
        rng = np.random.default_rng(0)
        for _ in range(4):
            eng.submit(rng.integers(1, 200, 6), 6, home="host0")
        eng.run(max_steps=200)
        assert eng.stats.host_decode_steps[0] > 0
        assert eng.stats.host_decode_steps[1] == 0    # never woke up


# ---------------------------------------------------------------------------
# DCN-priced rebalancing: the host-local mode
# ---------------------------------------------------------------------------

class TestDCNRebalanceMode:
    def _run(self, local: bool):
        eng = ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                            backend=StubModelBackend(),
                            cost_model=SERVE_COST, dcn_rebalance=local)
        rng = np.random.default_rng(0)
        n = 0
        for _ in range(12):
            eng.submit(rng.integers(1, 250, 8), 24, gang="fat",
                       home="host0")
            n += 1
        for h in range(4):
            for g in range(2):
                for _ in range(8):
                    eng.submit(rng.integers(1, 250, 8), 4,
                               gang=f"h{h}g{g}", home=f"page{2 * h}")
                    n += 1
        eng.run(max_steps=8000)
        assert len(eng.completed) == n
        return eng

    def test_local_mode_buys_host_local_respreads(self):
        """On admission-bound within-host skew the priced trigger buys
        host-local re-spreads; the flat trigger never does (it has no
        host-local candidates at all) and its machine-wide deal pays
        level-table tolls — more stall for more steps.  Either way the
        decode streams are identical: rebalance mode is pure
        scheduling."""
        local = self._run(True)
        flat = self._run(False)
        assert local.stats.local_rebalances > 0
        assert flat.stats.local_rebalances == 0
        assert local.steps < flat.steps
        assert {r.rid: tuple(r.out_tokens) for r in local.completed} == \
            {r.rid: tuple(r.out_tokens) for r in flat.completed}

    def test_single_host_modes_identical(self):
        """No tabled boundary on a single host: both rebalance modes make
        bit-identical decisions and bills (the goldens depend on it)."""
        def run(local):
            eng = ServingEngine(None, None, n_slots=8,
                                backend=StubModelBackend(),
                                dcn_rebalance=local)
            rng = np.random.default_rng(1)
            for i in range(20):
                eng.submit(rng.integers(1, 200, 6), 8,
                           gang="fat" if i < 14 else None)
            eng.run(max_steps=2000)
            return (eng.steps, eng.stats.rebalances,
                    eng.sched.stats.rebalance_cost,
                    {r.rid: tuple(r.out_tokens) for r in eng.completed})

        assert run(True) == run(False)


# ---------------------------------------------------------------------------
# HBM accounting under random traffic
# ---------------------------------------------------------------------------

class TestHBMAccounting:
    def _check_ledger(self, eng):
        for page, used in enumerate(eng.hbm_used):
            assert -1e-9 <= used <= eng.hbm_budget + 1e-9, \
                (page, used, eng.hbm_budget)
        recomputed = [0.0] * len(eng.hbm_used)
        for slot, charged in enumerate(eng._slot_charged):
            if charged:
                recomputed[eng._page_of[slot]] += eng.kv_bytes
        assert recomputed == pytest.approx(eng.hbm_used)

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10 ** 6),
           capacity_aware=st.booleans())
    def test_random_traffic_respects_budget_and_starves_nobody(
            self, seed, capacity_aware):
        rng = np.random.default_rng(seed)
        pods = int(rng.integers(1, 3))
        hosts = int(rng.integers(1, 3))
        n_hosts = pods * hosts
        n_slots = int(rng.integers(n_hosts, 4 * n_hosts + 1)) * 2
        budget = float(rng.integers(1, 4))
        eng = ServingEngine(None, None, n_slots=n_slots, group=4,
                            hosts=hosts, pods=pods,
                            backend=StubModelBackend(),
                            hbm_budget=budget, kv_bytes=1.0,
                            capacity_aware=capacity_aware)
        hostnames = [c.name for c in eng.topo.components("host")] \
            if n_hosts > 1 else [None]
        gangs, n = [], 0
        for g in range(int(rng.integers(2, 6))):
            gang = f"g{g}" if rng.random() < 0.8 else None
            if gang is not None:
                gangs.append(gang)
            home = hostnames[int(rng.integers(0, len(hostnames)))]
            for _ in range(int(rng.integers(1, 7))):
                eng.submit(rng.integers(1, 200, 6),
                           int(rng.integers(2, 9)),
                           prio=int(rng.integers(0, 3)), gang=gang,
                           home=home)
                n += 1
        steps = 0
        while not eng._drained() and steps < 6000:
            eng.step()
            steps += 1
            self._check_ledger(eng)
            if gangs and steps % 7 == 0:        # rolling backpressure
                eng.regenerate_gang(gangs[(steps // 7) % len(gangs)])
                self._check_ledger(eng)
        # refused loot was always re-admitted somewhere: every request
        # completed exactly once with exactly the asked-for tokens
        rids = sorted(r.rid for r in eng.completed)
        assert rids == list(range(n)), (n_slots, budget, len(rids), n)
        for r in eng.completed:
            assert len(r.out_tokens) == r.max_new_tokens
        assert all(u == 0.0 for u in eng.hbm_used)   # drained: all refunded

    def test_capacity_policy_never_changes_streams(self):
        """Aware and blind engines decode identical streams — capacity
        handling is pure scheduling."""
        def run(aware):
            eng = ServingEngine(None, None, n_slots=12, hosts=2,
                                backend=StubModelBackend(), hbm_budget=2.0,
                                capacity_aware=aware)
            rng = np.random.default_rng(3)
            for i in range(18):
                eng.submit(rng.integers(1, 200, 6), 8,
                           gang="fat" if i < 12 else None, home="host0")
            eng.run(max_steps=4000)
            return {r.rid: tuple(r.out_tokens) for r in eng.completed}

        assert run(True) == run(False)

    def test_full_group_refuses_steal_loot(self):
        """A page group at budget refuses in the survey: steal_refusals
        accounts it and no reservation ever exceeds the budget."""
        eng = ServingEngine(None, None, n_slots=8, hosts=2,
                            backend=StubModelBackend(), hbm_budget=1.0,
                            capacity_aware=True)
        rng = np.random.default_rng(0)
        for _ in range(12):
            eng.submit(rng.integers(1, 200, 6), 8, gang="fat", home="host0")
        eng.run(max_steps=2000)
        assert len(eng.completed) == 12
        assert eng.sched.stats.steal_refusals > 0
        assert eng.stats.hbm_slot_waits > 0         # parked, never bounced
        assert eng.stats.hbm_refusals == 0          # aware mode: no bounces


# ---------------------------------------------------------------------------
# rebalance-candidate scoping: keyed by component identity, not .index
# ---------------------------------------------------------------------------

class TestRebalanceCandidateScoping:
    @settings(max_examples=40)
    @given(cfg=fleet(), skew_host=st.integers(min_value=0, max_value=15))
    def test_skewed_host_is_candidate_by_identity(self, cfg, skew_host):
        """`_rebalance_candidates` must scope a re-spread to the exact
        host COMPONENT whose own page depths are skewed, on any 1-4 pod x
        ragged-host fleet.  The old lookup round-tripped the component
        through ``topo.components("host")[component.index]`` — an
        identity the Topology API never promises a consumer — so this
        pins the contract: the candidate *is* the skewed host object."""
        pods, hosts, group, n_slots = cfg
        eng = ServingEngine(None, None, n_slots=n_slots, group=group,
                            pods=pods, hosts=hosts,
                            backend=StubModelBackend())
        if eng._host_idx is None:
            return                      # single host: no host candidates
        host_comps = eng.topo.components("host")
        target = host_comps[skew_host % len(host_comps)]
        own_pages = [p for p, h in enumerate(eng._page_host)
                     if h is target]
        if len(own_pages) < 2:
            return                      # one-page host: skew undefined
        depths = [0] * len(eng._page_host)
        depths[own_pages[0]] = eng.depth_skew       # skew inside target only
        cands = eng._rebalance_candidates(depths)
        assert cands[-1] is None                     # machine-wide fallback
        assert len(cands) == 2
        assert cands[0] is target, \
            (cands[0].name if cands[0] else None, target.name)

    def test_all_skewed_hosts_enumerated(self):
        """Every host with internal skew appears, each by identity, in
        page order."""
        eng = ServingEngine(None, None, n_slots=24, group=3, pods=2,
                            hosts=3, backend=StubModelBackend())
        depths = [0] * len(eng._page_host)
        skewed = []
        seen = set()
        for p, h in enumerate(eng._page_host):
            if id(h) not in seen:
                seen.add(id(h))
                depths[p] = eng.depth_skew + 1
                skewed.append(h)
        cands = eng._rebalance_candidates(depths)
        assert cands[-1] is None
        assert all(a is b for a, b in zip(cands[:-1], skewed))
        assert len(cands) == len(skewed) + 1
