"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import ref
from repro.kernels import rglru as rglru_k
from repro.kernels import rwkv6 as rwkv_k

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, k=0):
    return jax.random.normal(jax.random.PRNGKey(k), shape, jnp.float32) \
        .astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,hd", [
        (1, 128, 2, 2, 64),     # MHA
        (2, 256, 4, 2, 64),     # GQA 2:1
        (1, 512, 8, 1, 128),    # MQA, MXU-aligned hd
        (2, 384, 4, 4, 32),     # non-pow2 seq (block clamp)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, K, hd, dtype):
        q = _rand((B, S, H, hd), dtype, 1)
        k = _rand((B, S, K, hd), dtype, 2)
        v = _rand((B, S, K, hd), dtype, 3)
        scale = hd ** -0.5
        out = fa.mha(q, k, v, causal=True, scale=scale, bq=128, bk=128)
        g = H // K
        kr = jnp.repeat(k, g, axis=2) if g > 1 else k
        vr = jnp.repeat(v, g, axis=2) if g > 1 else v
        want = ref.sdpa_ref(q, kr, vr, causal=True, scale=scale)
        atol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=atol)

    @pytest.mark.parametrize("window", [64, 128, 500])
    def test_sliding_window(self, window):
        B, S, H, hd = 1, 256, 2, 64
        q, k, v = (_rand((B, S, H, hd), jnp.float32, i) for i in range(3))
        out = fa.mha(q, k, v, causal=True, window=window, scale=0.125,
                     bq=64, bk=64)
        want = ref.sdpa_ref(q, k, v, causal=True, window=window, scale=0.125)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-6)

    def test_block_shape_independence(self):
        B, S, H, hd = 1, 512, 2, 64
        q, k, v = (_rand((B, S, H, hd), jnp.float32, i) for i in range(3))
        outs = [fa.mha(q, k, v, scale=0.125, bq=bq, bk=bk)
                for bq, bk in ((64, 64), (128, 256), (512, 128))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       atol=2e-6)


class TestRGLRU:
    @pytest.mark.parametrize("B,S,N", [(1, 128, 128), (2, 256, 256),
                                       (3, 96, 512)])
    @pytest.mark.parametrize("chunk", [32, 128])
    def test_matches_ref(self, B, S, N, chunk):
        a = jax.nn.sigmoid(_rand((B, S, N), jnp.float32, 1))  # decay in (0,1)
        b = _rand((B, S, N), jnp.float32, 2)
        h = rglru_k.lru_scan(a, b, chunk=chunk)
        want = ref.lru_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_state_continuity_across_chunks(self):
        """Chunked result must equal unchunked (state carried in VMEM)."""
        B, S, N = 1, 256, 128
        a = jax.nn.sigmoid(_rand((B, S, N), jnp.float32, 1))
        b = _rand((B, S, N), jnp.float32, 2)
        h1 = rglru_k.lru_scan(a, b, chunk=256)
        h2 = rglru_k.lru_scan(a, b, chunk=32)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-6, atol=1e-6)


class TestRWKV6:
    @pytest.mark.parametrize("B,S,H,hd", [(1, 64, 2, 32), (2, 128, 4, 64)])
    @pytest.mark.parametrize("chunk", [32, 64])
    def test_matches_ref(self, B, S, H, hd, chunk):
        r = _rand((B, S, H, hd), jnp.float32, 1)
        k = _rand((B, S, H, hd), jnp.float32, 2)
        v = _rand((B, S, H, hd), jnp.float32, 3)
        w = jax.nn.sigmoid(_rand((B, S, H, hd), jnp.float32, 4)) * 0.9
        u = _rand((H, hd), jnp.float32, 5) * 0.3
        y, sf = rwkv_k.wkv(r, k, v, w, u, chunk=chunk)
        want_y, want_s = ref.wkv_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want_y),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(want_s),
                                   rtol=1e-4, atol=1e-4)

    def test_final_state_hands_off_to_decode(self):
        """Running WKV on [x1;x2] == running x1, then x2 from x1's state."""
        B, S, H, hd = 1, 64, 2, 32
        r = _rand((B, 2 * S, H, hd), jnp.float32, 1)
        k = _rand((B, 2 * S, H, hd), jnp.float32, 2)
        v = _rand((B, 2 * S, H, hd), jnp.float32, 3)
        w = jax.nn.sigmoid(_rand((B, 2 * S, H, hd), jnp.float32, 4)) * 0.9
        u = _rand((H, hd), jnp.float32, 5) * 0.3
        y_full, _ = ref.wkv_ref(r, k, v, w, u)
        _, s1 = rwkv_k.wkv(r[:, :S], k[:, :S], v[:, :S], w[:, :S], u)
        # continue second half step-by-step from s1
        S_ = np.asarray(s1)
        ys = []
        for t in range(S, 2 * S):
            kv = np.asarray(k[0, t])[:, :, None] * np.asarray(v[0, t])[:, None, :]
            out = np.einsum("hk,hkv->hv", np.asarray(r[0, t]),
                            S_[0] + np.asarray(u)[:, :, None] * kv)
            S_ = (np.asarray(w[0, t])[:, :, None] * S_[0] + kv)[None]
            ys.append(out)
        got = np.stack(ys)[None]
        np.testing.assert_allclose(got, np.asarray(y_full[:, S:]),
                                   rtol=1e-4, atol=1e-4)


class TestModelIntegration:
    """The model code paths with use_kernel=True agree with kernel-off."""

    def test_attention_kernel_path(self):
        from repro.configs import get_config
        from repro.models import api, lm
        c = get_config("yi-6b").reduced(n_layers=2)
        params = api.init(c, KEY)
        B, S = 1, 128
        toks = jax.random.randint(KEY, (B, S), 0, c.vocab)
        h = lm._inputs_to_h(params, {"tokens": toks}, c)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        o1, _, _ = lm.backbone(params, h, pos, c, use_kernel=False)
        o2, _, _ = lm.backbone(params, h, pos, c, use_kernel=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-4)

    def test_rglru_kernel_path(self):
        from repro.configs import get_config
        from repro.models import api, lm
        c = get_config("recurrentgemma-9b").reduced()
        params = api.init(c, KEY)
        B, S = 1, 128
        toks = jax.random.randint(KEY, (B, S), 0, c.vocab)
        h = lm._inputs_to_h(params, {"tokens": toks}, c)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        o1, _, _ = lm.backbone(params, h, pos, c, use_kernel=False)
        o2, _, _ = lm.backbone(params, h, pos, c, use_kernel=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-3, atol=1e-3)

    def test_rwkv_kernel_path(self):
        from repro.configs import get_config
        from repro.models import api, lm
        c = get_config("rwkv6-3b").reduced()
        params = api.init(c, KEY)
        B, S = 1, 64
        toks = jax.random.randint(KEY, (B, S), 0, c.vocab)
        h = lm._inputs_to_h(params, {"tokens": toks}, c)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        o1, _, _ = lm.backbone(params, h, pos, c, use_kernel=False)
        o2, _, _ = lm.backbone(params, h, pos, c, use_kernel=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-3, atol=1e-3)


class TestFlashJnp:
    """The custom-VJP jnp flash (production path) vs materialised ref."""

    @pytest.mark.parametrize("window", [None, 96])
    def test_fwd_bwd(self, window):
        from repro.models.flash import flash_attention as fj
        B, S, H, hd = 1, 256, 2, 32
        q, k, v = (_rand((B, S, H, hd), jnp.float32, i) for i in range(3))
        scale = hd ** -0.5
        out = fj(q, k, v, causal=True, window=window, scale=scale, block=64)
        want = ref.sdpa_ref(q, k, v, causal=True, window=window, scale=scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-6)
        g1 = jax.grad(lambda *a: fj(*a, causal=True, window=window,
                                    scale=scale, block=64).sum(),
                      argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda *a: ref.sdpa_ref(*a, causal=True, window=window,
                                              scale=scale).sum(),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
