"""SchedulerRuntime unit tests: the extracted decision loop itself.

The two consumers pin the integration behaviour elsewhere (the simulator
via the golden traces, the serving engine via tests/test_serving.py);
these tests cover the runtime's own contract — acquire/billing, the
first/next-touch data policy with the migration callback, the cost-benefit
rebalance trigger, and the counter-delta ledger.
"""

import pytest

from repro.core import (SchedulerRuntime, SimplePolicy, StealCostModel,
                        StealPolicy, bubble, novascale_16, rebalance_worth_it,
                        thread)
from repro.core.scheduler import BubbleScheduler


def _runtime(**kw):
    topo = novascale_16()
    pol = StealPolicy(topo, cost_model=kw.pop("cost_model", StealCostModel()))
    return SchedulerRuntime(topo, pol, **kw), pol


class TestDataPolicyResolution:
    def test_policy_preference_wins_over_default(self):
        rt, _ = _runtime()
        assert rt.data_policy == "next_touch"        # StealPolicy preference

    def test_explicit_arg_wins_over_preference(self):
        rt, _ = _runtime(data_policy="first_touch")
        assert rt.data_policy == "first_touch"

    def test_flat_policy_defaults_to_first_touch(self):
        topo = novascale_16()
        rt = SchedulerRuntime(topo, SimplePolicy(topo))
        assert rt.data_policy == "first_touch"
        assert rt.sched is None
        assert rt.counters() == {k: 0 for k in rt.SCHED_COUNTERS}
        assert not rt.rebalance_worth_it(1e9)        # nothing to re-spread
        assert rt.rebalance(0) == 0


class TestTouch:
    def test_first_toucher_homes_data(self):
        rt, _ = _runtime()
        t = thread(4.0, data="page")
        assert rt.touch(3, t) == (3, False)
        assert rt.homes["page"] == 3
        assert rt.touch(9, t) == (3, False)          # not stolen: stays put

    def test_stolen_thread_rehomes_once(self):
        moved = []
        rt, _ = _runtime(on_data_migrate=lambda *a: moved.append(a))
        t = thread(4.0, data="page")
        rt.homes["page"] = 12
        t.stolen = True
        assert rt.touch(0, t) == (0, True)
        assert rt.homes["page"] == 0
        assert rt.data_migrations == 1
        assert moved == [("page", 12, 0)]
        assert not t.stolen                           # flag is one-shot
        assert rt.touch(0, t) == (0, False)           # now local for real
        assert rt.migration_log == [("page", 12, 0)]

    def test_first_touch_policy_consumes_flag_without_moving(self):
        rt, _ = _runtime(data_policy="first_touch")
        t = thread(4.0, data="page")
        rt.homes["page"] = 12
        t.stolen = True
        assert rt.touch(0, t) == (12, False)
        assert rt.data_migrations == 0 and not t.stolen

    def test_dataless_thread_never_homes(self):
        rt, _ = _runtime()
        t = thread(4.0)
        t.stolen = True
        assert rt.touch(5, t) == (5, False)
        assert rt.homes == {} and not t.stolen


class TestAcquireBilling:
    def test_acquire_returns_thread_and_steal_bill(self):
        cm = StealCostModel(lock_penalty=2.0, level_penalty=4.0,
                            thread_penalty=1.0)
        rt, pol = _runtime(cost_model=cm)
        grp = bubble(thread(2.0), thread(2.0), name="grp")
        pol.sched.queues.queue_of(rt.topo.components("node")[3]).push(grp)
        t, cost = rt.acquire(0)
        assert t is not None
        assert cost == pytest.approx(2.0 + 4.0 * 2 + 1.0 * 2)
        _, again = rt.acquire(1)
        assert again == 0.0                           # bill drained once

    def test_release_returns_thread_to_policy(self):
        rt, pol = _runtime()
        pol.sched.submit_thread(thread(2.0, name="t"))
        t, _ = rt.acquire(0)
        assert pol.running[0] is t
        rt.release(0, t, True)
        assert 0 not in pol.running


class TestRebalanceWorthIt:
    CM = StealCostModel(lock_penalty=1.0, rebalance_base=2.0,
                        rebalance_per_move=0.5)

    def _loaded(self):
        rt, pol = _runtime(cost_model=self.CM)
        for _ in range(6):
            pol.sched.queues.global_queue().push(thread(3.0))
        return rt, pol

    def test_spend_below_base_cost_never_triggers(self):
        rt, _ = self._loaded()
        assert not rt.rebalance_worth_it(2.0)         # <= rebalance_base
        assert not rebalance_worth_it(rt.sched, 0.0)

    def test_spend_above_bill_triggers(self):
        rt, _ = self._loaded()
        bill = self.CM.rebalance_cost(6)              # 2.0 + 3.0
        assert rt.rebalance_worth_it(bill + 0.1)
        assert not rt.rebalance_worth_it(bill)        # strict >

    def test_min_backlog_gates(self):
        rt, _ = self._loaded()
        assert not rt.rebalance_worth_it(100.0, min_backlog=7)
        assert rt.rebalance_worth_it(100.0, min_backlog=6)

    def test_rebalance_bills_through_next_acquire(self):
        rt, pol = self._loaded()
        moves = rt.rebalance(0)
        assert moves == 6
        t, cost = rt.acquire(0)
        assert cost == pytest.approx(self.CM.rebalance_cost(6))


class TestLedger:
    def test_counter_deltas_isolate_runs(self):
        rt, pol = _runtime(cost_model=StealCostModel(lock_penalty=1.0))
        pol.sched.queues.queue_of(rt.topo.components("node")[2]).push(
            bubble(thread(2.0), name="g"))
        before = rt.counters()
        t, _ = rt.acquire(0)
        assert t is not None
        delta = rt.counter_deltas(before, rt.counters())
        assert delta["steals"] == 1
        assert delta["steal_cost"] == pytest.approx(1.0)
