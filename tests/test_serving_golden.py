"""Golden-trace regression tests for the serving engine.

The simulator's golden traces (``tests/test_golden.py``) pin the
scheduler's behaviour on the paper's workloads; these pin the *serving*
stack — stub-backend decode streams plus the engine/scheduler counter
ledger — per engine mode and topology, single-host and multi-host:

* ``single_skew`` — the PR 3 skewed-gang workload on 8 slots, in both
  ``admission`` and ``runtime`` modes;
* ``single_churn`` — gang regeneration (KV park + batched splice) under
  steal traffic;
* ``multihost_skew`` — the pod-sharded fleet (2 pods x 2 hosts), with the
  DCN-priced cost table (``dcn``) and the flat-ranking/DCN-billed naive
  engine (``naive``, which also keeps the flat machine-wide rebalance
  mode — a DCN-naive engine does not know hosts exist);
* ``hbm_pressure`` — per-page-group HBM budgets, capacity-``aware`` vs
  capacity-``blind`` (rebalance mode pinned flat in both, isolating the
  capacity variable — matching ``benchmarks/serve_gangs.py``);
* ``dcn_rebalance`` — the DCN-priced rebalance path: admission-bound
  within-host skew on every host; ``local`` quotes re-spreads through the
  boundary-priced estimate and buys host-local page shuffles, ``flat``
  keeps the flat-quoted machine-wide deal and pays its level-table tolls
  as admission freezes on the receiving page groups;
* ``open_loop`` — the PR 6 open-loop SLA workload (seeded Poisson
  arrivals, heavy-tailed lengths, interactive/standard/batch classes) on
  8 slots x 2 hosts: ``fifo`` holds slots in arrival order, ``sla`` runs
  WDRR admission + multilevel-feedback demotion + batch-gang preemption
  (the snapshot additionally pins the preemption/demotion counters);
* ``agentic_tool`` — tool calls mid-decode on a single host (agentic
  singles, an agentic gang, plain backlog): ``sleep`` parks KV and frees
  the slot at each marker, ``hold`` keeps the slot through the think gap
  — the snapshot pins the sleep/wake/affinity counters and the shared
  digest proves blocking policy never changes tokens;
* ``agentic_paged`` — a multi-turn session on the paged jax backend: the
  woken session's prefix KV pages are still resident, so every wake is a
  block-table re-point (``table_splices``) with **zero** pool copies and
  no re-prefill.

Each snapshot records the engine step count, a digest of every completed
request's full decode stream (the stub backend hashes token history, so
*any* KV mishandling — lost splice, stale slot, wrong-slot write, a
budget overcommit — changes the digest), and the counters that describe
the schedule.  Everything is deterministic: prompts come from a seeded
generator and the engine has no RNG.

To regenerate after an *intentional* behaviour change::

    PYTHONPATH=src python tests/test_serving_golden.py

and paste the printed dict over ``GOLDEN``.  CI's golden-drift job runs::

    PYTHONPATH=src python tests/test_serving_golden.py --check

which regenerates every snapshot and fails (exit 1, printing the drifted
entries) if any differs from the committed dict.
"""

import hashlib

import numpy as np
import pytest

from repro.core import reset_ids
from repro.core.scheduler import StealCostModel  # noqa: F401  (re-export)
from repro.serving import (FLAT_SERVE_COST, SERVE_COST, ServingEngine,
                           StubModelBackend)

COUNTER_KEYS = ("steals", "steal_refusals", "rebalances", "kv_migrations",
                "kv_page_moves", "kv_host_moves", "kv_parks", "prefills",
                "hbm_slot_waits", "hbm_refusals")


def _submit(eng: ServingEngine, spec, seed: int = 0) -> int:
    """spec: (gang, count, prio, home, new_tokens); returns count."""
    rng = np.random.default_rng(seed)
    n = 0
    for gang, count, prio, home, new_tokens in spec:
        for _ in range(count):
            eng.submit(rng.integers(1, 250, 8), new_tokens, prio=prio,
                       gang=gang, home=home)
            n += 1
    return n


def _snapshot(eng: ServingEngine, n: int) -> dict:
    """Snapshot streams + ledger for a drained engine."""
    assert len(eng.completed) == n, (len(eng.completed), n)
    digest = hashlib.blake2b(
        repr(sorted((r.rid, tuple(r.out_tokens))
                    for r in eng.completed)).encode(),
        digest_size=8).hexdigest()
    c = eng.counters()
    snap = {"steps": eng.steps, "streams": digest}
    snap.update({k: c[k] for k in COUNTER_KEYS})
    snap["stall_steps"] = round(c["stall_steps"], 4)
    return snap


def _drive(eng: ServingEngine, n: int, regen=()) -> dict:
    """Run to drain (bounded), snapshot streams + ledger."""
    regen = dict(regen)                     # step -> gang to regenerate
    steps = 0
    while not eng._drained() and steps < 8000:
        eng.step()
        steps += 1
        gang = regen.get(steps)
        if gang is not None:
            eng.regenerate_gang(gang)
    return _snapshot(eng, n)


SINGLE_SKEW = [("fat", 16, 0, None, 12), ("a", 2, 2, None, 12),
               ("b", 1, 1, None, 12), (None, 2, 1, None, 12)]
SINGLE_CHURN = [(f"g{i}", 2, i % 3, None, 12) for i in range(8)]
# the benchmark's skewed-pod shape: heavy fat threads on host0 tempt a
# flat-cost victim ranking across the DCN while light local backlog waits
MULTI_SKEW = ([("fat", 16, 0, "host0", 28)] +
              [(f"h{h}g{g}", 8, 0, f"page{2 * h}", 12)
               for h in range(1, 4) for g in range(2)])
HBM = [("fat", 24, 0, "host0", 10), (None, 6, 1, "host1", 6)]
# the benchmark's dcn-rebalance shape: short small requests (admission-
# bound) with every host's own backlog homed on its FIRST page list
DCN_REB = ([("fat", 12, 0, "host0", 24)] +
           [(f"h{h}g{g}", 8, 0, f"page{2 * h}", 4)
            for h in range(4) for g in range(2)])


def build(case: str, variant: str) -> tuple[ServingEngine, list, tuple]:
    stub = StubModelBackend()
    if case == "single_skew":
        eng = ServingEngine(None, None, n_slots=8, backend=stub,
                            mode=variant)
        return eng, SINGLE_SKEW, ()
    if case == "single_churn":
        eng = ServingEngine(None, None, n_slots=8, backend=stub,
                            mode=variant)
        return eng, SINGLE_CHURN, ((4, "g1"), (8, "g5"))
    if case == "multihost_skew":
        cost, bill = (SERVE_COST, None) if variant == "dcn" else \
            (FLAT_SERVE_COST, SERVE_COST)
        eng = ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                            backend=stub, cost_model=cost, bill_model=bill,
                            dcn_rebalance=(variant == "dcn"))
        return eng, MULTI_SKEW, ()
    if case == "dcn_rebalance":
        eng = ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                            backend=stub, cost_model=SERVE_COST,
                            dcn_rebalance=(variant == "local"))
        return eng, DCN_REB, ()
    assert case == "hbm_pressure", case
    eng = ServingEngine(None, None, n_slots=16, hosts=2, backend=stub,
                        hbm_budget=2.0, kv_bytes=1.0,
                        capacity_aware=(variant == "aware"),
                        dcn_rebalance=False)
    return eng, HBM, ()


def simulate(case: str, variant: str) -> dict:
    reset_ids()
    if case == "open_loop":
        # open-loop: arrivals come from the seeded workload trace and are
        # submitted at their arrival steps by drive(), not batched up front
        from repro.serving import SLA_CLASSES, drive, make_trace
        trace = make_trace(steps=48, rate=1.2, seed=3)
        stub = StubModelBackend()
        if variant == "sla":
            eng = ServingEngine(None, None, n_slots=8, group=2, hosts=2,
                                backend=stub, sla_classes=SLA_CLASSES,
                                preempt=True, preempt_cooldown=4)
        else:
            assert variant == "fifo", variant
            eng = ServingEngine(None, None, n_slots=8, group=2, hosts=2,
                                backend=stub, mode="admission")
        drive(eng, trace)
        snap = _snapshot(eng, len(trace))
        c = eng.counters()
        snap.update({k: c[k] for k in ("preemptions", "preempt_parks",
                                       "demotions")})
        return snap
    if case == "agentic_tool":
        # tool calls mid-decode, single host: agentic singles, one agentic
        # gang (members share the schedule, so it sleeps/wakes together),
        # plain backlog that inherits the freed slots under ``sleep``
        eng = ServingEngine(None, None, n_slots=8,
                            backend=StubModelBackend(),
                            agentic_sleep=(variant == "sleep"))
        rng = np.random.default_rng(5)
        n = 0
        for _ in range(4):
            eng.submit(rng.integers(1, 250, 8), 12,
                       tool_calls=((4, 6), (8, 3)))
            n += 1
        for _ in range(2):
            eng.submit(rng.integers(1, 250, 8), 12, gang="ag",
                       tool_calls=((6, 8),))
            n += 1
        for _ in range(8):
            eng.submit(rng.integers(1, 250, 8), 10)
            n += 1
        snap = _drive(eng, n)
        c = eng.counters()
        snap.update({k: c[k] for k in ("sleeps", "holds", "wakes",
                                       "wake_home", "wake_away",
                                       "wake_reprefills")})
        return snap
    if case == "agentic_paged":
        # a multi-turn session through the paged backend: both wakes find
        # the prefix KV pages resident — block-table re-points, zero pool
        # copies, no re-prefill
        import jax
        from repro.configs import get_config
        from repro.models import api
        from repro.serving import PagedJaxModelBackend
        cfg = get_config("yi-6b").reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        pb = PagedJaxModelBackend(cfg, params, 32, page_size=8)
        eng = ServingEngine(cfg, params, n_slots=4, cache_len=32,
                            backend=pb)
        rng = np.random.default_rng(7)
        eng.submit(rng.integers(1, 97, 6), 10, tool_calls=((3, 4), (6, 3)))
        eng.submit(rng.integers(1, 97, 5), 6)
        snap = _drive(eng, 2)
        c = eng.counters()
        snap.update({k: c[k] for k in ("sleeps", "wakes",
                                       "wake_reprefills")})
        snap["pool_copies"] = pb.stats["pool_copies"]
        snap["table_splices"] = pb.stats["table_splices"]
        assert snap["pool_copies"] == 0 and snap["wake_reprefills"] == 0
        return snap
    eng, spec, regen = build(case, variant)
    n = _submit(eng, spec)
    return _drive(eng, n, regen)


CASES = [("single_skew", "admission"), ("single_skew", "runtime"),
         ("single_churn", "runtime"),
         ("multihost_skew", "naive"), ("multihost_skew", "dcn"),
         ("hbm_pressure", "blind"), ("hbm_pressure", "aware"),
         ("dcn_rebalance", "flat"), ("dcn_rebalance", "local"),
         ("open_loop", "fifo"), ("open_loop", "sla"),
         ("agentic_tool", "hold"), ("agentic_tool", "sleep"),
         ("agentic_paged", "paged")]


# ---------------------------------------------------------------------------
# snapshots (regenerate: PYTHONPATH=src python tests/test_serving_golden.py)
# ---------------------------------------------------------------------------

GOLDEN = {
    ('single_skew', 'admission'): {'steps': 55, 'streams': 'dbb35fc690fba08b', 'steals': 0, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 0, 'kv_page_moves': 0, 'kv_host_moves': 0, 'kv_parks': 0, 'prefills': 21, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 0.0},
    ('single_skew', 'runtime'): {'steps': 35, 'streams': 'dbb35fc690fba08b', 'steals': 6, 'steal_refusals': 0, 'rebalances': 1, 'kv_migrations': 6, 'kv_page_moves': 2, 'kv_host_moves': 0, 'kv_parks': 0, 'prefills': 21, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 8.375},
    ('single_churn', 'runtime'): {'steps': 22, 'streams': 'a378043789385b15', 'steals': 0, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 0, 'kv_page_moves': 0, 'kv_host_moves': 0, 'kv_parks': 4, 'prefills': 16, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 0.0},
    ('multihost_skew', 'naive'): {'steps': 82, 'streams': '55cfc4500c9ca06d', 'steals': 17, 'steal_refusals': 0, 'rebalances': 2, 'kv_migrations': 31, 'kv_page_moves': 18, 'kv_host_moves': 13, 'kv_parks': 0, 'prefills': 64, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 809.75},
    ('multihost_skew', 'dcn'): {'steps': 65, 'streams': '55cfc4500c9ca06d', 'steals': 22, 'steal_refusals': 0, 'rebalances': 2, 'kv_migrations': 34, 'kv_page_moves': 9, 'kv_host_moves': 4, 'kv_parks': 0, 'prefills': 64, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 296.625},
    ('hbm_pressure', 'blind'): {'steps': 55, 'streams': 'ed6dbeec973b4ef5', 'steals': 35, 'steal_refusals': 0, 'rebalances': 2, 'kv_migrations': 16, 'kv_page_moves': 11, 'kv_host_moves': 6, 'kv_parks': 0, 'prefills': 30, 'hbm_slot_waits': 0, 'hbm_refusals': 173, 'stall_steps': 261.25},
    ('hbm_pressure', 'aware'): {'steps': 37, 'streams': 'ed6dbeec973b4ef5', 'steals': 4, 'steal_refusals': 18, 'rebalances': 1, 'kv_migrations': 4, 'kv_page_moves': 2, 'kv_host_moves': 1, 'kv_parks': 0, 'prefills': 30, 'hbm_slot_waits': 228, 'hbm_refusals': 0, 'stall_steps': 24.75},
    ('dcn_rebalance', 'flat'): {'steps': 64, 'streams': '90b7d19ba0bb5e62', 'steals': 17, 'steal_refusals': 0, 'rebalances': 1, 'kv_migrations': 32, 'kv_page_moves': 11, 'kv_host_moves': 9, 'kv_parks': 0, 'prefills': 76, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 483.125},
    ('dcn_rebalance', 'local'): {'steps': 39, 'streams': '90b7d19ba0bb5e62', 'steals': 19, 'steal_refusals': 0, 'rebalances': 1, 'kv_migrations': 36, 'kv_page_moves': 5, 'kv_host_moves': 4, 'kv_parks': 0, 'prefills': 76, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 298.5},
    ('open_loop', 'fifo'): {'steps': 125, 'streams': '76c37afcead250e6', 'steals': 0, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 0, 'kv_page_moves': 0, 'kv_host_moves': 0, 'kv_parks': 0, 'prefills': 54, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 0.0, 'preemptions': 0, 'preempt_parks': 0, 'demotions': 0},
    ('open_loop', 'sla'): {'steps': 112, 'streams': '76c37afcead250e6', 'steals': 3, 'steal_refusals': 0, 'rebalances': 2, 'kv_migrations': 6, 'kv_page_moves': 3, 'kv_host_moves': 2, 'kv_parks': 6, 'prefills': 54, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 29.375, 'preemptions': 4, 'preempt_parks': 6, 'demotions': 0},
    ('agentic_tool', 'hold'): {'steps': 36, 'streams': 'db5874ed0bb3a591', 'steals': 0, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 0, 'kv_page_moves': 0, 'kv_host_moves': 0, 'kv_parks': 0, 'prefills': 14, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 0.0, 'sleeps': 0, 'holds': 10, 'wakes': 10, 'wake_home': 0, 'wake_away': 0, 'wake_reprefills': 0},
    ('agentic_tool', 'sleep'): {'steps': 28, 'streams': 'db5874ed0bb3a591', 'steals': 2, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 6, 'kv_page_moves': 5, 'kv_host_moves': 0, 'kv_parks': 10, 'prefills': 14, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 2.875, 'sleeps': 10, 'holds': 0, 'wakes': 10, 'wake_home': 5, 'wake_away': 5, 'wake_reprefills': 0},
    ('agentic_paged', 'paged'): {'steps': 14, 'streams': '38499d22f18a0589', 'steals': 0, 'steal_refusals': 0, 'rebalances': 0, 'kv_migrations': 0, 'kv_page_moves': 0, 'kv_host_moves': 0, 'kv_parks': 2, 'prefills': 2, 'hbm_slot_waits': 0, 'hbm_refusals': 0, 'stall_steps': 0.0, 'sleeps': 2, 'wakes': 2, 'wake_reprefills': 0, 'pool_copies': 0, 'table_splices': 2},
}


@pytest.mark.parametrize("case,variant", CASES)
def test_serving_golden_trace(case: str, variant: str):
    got = simulate(case, variant)
    want = GOLDEN[(case, variant)]
    assert got == want, (case, variant, got, want)


def test_mode_never_changes_streams():
    """Scheduling (steal pricing, capacity policy) must never change what
    was decoded — the digests across variants of one case are equal."""
    by_case: dict = {}
    for case, variant in CASES:
        by_case.setdefault(case, set()).add(GOLDEN[(case, variant)]["streams"])
    for case, digests in by_case.items():
        assert len(digests) == 1, (case, digests)


def generate() -> dict:
    return {(case, variant): simulate(case, variant)
            for case, variant in CASES}


def format_golden(snapshots: dict) -> str:
    lines = ["GOLDEN = {"]
    lines += [f"    {k!r}: {v!r}," for k, v in snapshots.items()]
    lines.append("}")
    return "\n".join(lines)


def check_drift(out_path=None) -> int:
    """Regenerate all snapshots; report any that differ from GOLDEN."""
    regen = generate()
    if out_path:
        with open(out_path, "w") as f:
            f.write(format_golden(regen) + "\n")
    drifted = {k: (GOLDEN.get(k), v) for k, v in regen.items()
               if GOLDEN.get(k) != v}
    missing = sorted(k for k in GOLDEN if k not in regen)
    if not drifted and not missing:
        print(f"serving golden traces stable: {len(regen)} snapshots match")
        return 0
    for k, (want, got) in sorted(drifted.items()):
        print(f"DRIFT {k}:\n  committed:   {want!r}\n  regenerated: {got!r}")
    for k in missing:
        print(f"MISSING {k}: committed but no longer generated")
    print(f"{len(drifted)} drifted, {len(missing)} missing — if intentional, "
          "regenerate with `PYTHONPATH=src python tests/test_serving_golden"
          ".py` and paste over GOLDEN")
    return 1


if __name__ == "__main__":
    import sys
    argv = sys.argv[1:]
    if "--check" in argv:
        out = None
        if "--out" in argv:
            out = argv[argv.index("--out") + 1]
        sys.exit(check_drift(out))
    print(format_golden(generate()))
