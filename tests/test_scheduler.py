"""Unit + property tests for the bubble scheduler core.

The property tests prefer real `hypothesis`; in a clean environment they
fall back to the deterministic shim in ``tests/_hypothesis_shim.py`` so
tier-1 always collects and runs.
"""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core import (BubbleScheduler, QueueHierarchy, Topology, Level,
                        balanced_tree, bubble, novascale_16, numa_4x4_smt,
                        thread, tpu_pod_slice)


class TestTopology:
    def test_novascale(self):
        t = novascale_16()
        assert t.n_cpus == 16
        assert [l.name for l in t.levels] == ["machine", "node", "cpu"]

    def test_covering_order_local_to_global(self):
        t = novascale_16()
        names = [c.level.name for c in t.covering(5)]
        assert names == ["cpu", "node", "machine"]

    def test_distance_factor(self):
        t = novascale_16()
        assert t.distance_factor(0, 1) == 1.0        # same node
        assert t.distance_factor(0, 4) == 3.0        # cross node
        assert t.distance_factor(7, 7) == 1.0

    def test_tpu_pod_slice(self):
        t = tpu_pod_slice(pods=2, data=16, model=16)
        assert t.n_cpus == 512
        assert t.distance_factor(0, 256) == 12.0     # cross pod (DCN)
        assert t.distance_factor(0, 16) == 2.5       # cross data slice


class TestTwoPassLookup:
    def test_priority_beats_locality(self):
        topo = novascale_16()
        q = QueueHierarchy(topo)
        lo = thread(1.0, name="lo", prio=0)
        hi = thread(1.0, name="hi", prio=5)
        q.covering(0)[0].push(lo)         # most local list of cpu0
        q.global_queue().push(hi)         # global list
        got = q.find(0)
        assert got is not None and got[1] is hi   # paper §3.3.2

    def test_local_wins_ties(self):
        topo = novascale_16()
        q = QueueHierarchy(topo)
        a = thread(1.0, name="a", prio=1)
        b = thread(1.0, name="b", prio=1)
        q.covering(0)[0].push(a)
        q.global_queue().push(b)
        got = q.find(0)
        assert got[1] is a

    def test_steal_prefers_bubbles(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        b = bubble(thread(5.0), thread(5.0), name="grp")
        t = thread(1.0, name="solo")
        # put work on node1's queue; cpu0 (node0) must steal
        node1 = topo.components("node")[1]
        sched.queues.queue_of(node1).push(t)
        sched.queues.queue_of(node1).push(b)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is b


class TestBurstHeuristic:
    def test_four_groups_burst_at_nodes(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        root = balanced_tree([4, 4], work=10.0)
        sched.wake_up_bubble(root)
        # drive every cpu once; group bubbles must land on node queues
        for cpu in range(16):
            sched.next_thread(cpu)
        assert sched.stats.bursts >= 4
        # every thread got scheduled within a node whose queue held its group
        assert sched.stats.schedules == 16

    def test_explicit_burst_level_respected(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        b = bubble(*[thread(1.0) for _ in range(4)], burst_level="machine")
        sched.wake_up_bubble(b)
        t = sched.next_thread(0)
        assert t is not None
        # burst happened on the machine (global) list, not a node list
        assert sched.queues.global_queue().level == "machine"
        assert b.home_list is sched.queues.global_queue()


class TestRegeneration:
    def test_regenerate_recloses_bubble(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        b = bubble(*[thread(10.0) for _ in range(4)])
        sched.wake_up_bubble(b)
        t = sched.next_thread(0)
        assert t is not None
        # regenerate while one thread is "running"
        sched.regenerate(b, running={0: t})
        assert not b.burst
        # queues hold no loose children of b (except the closed b awaiting)
        for q in sched.queues.queues.values():
            for task in q.tasks:
                assert task.parent is not b or task is b
        # running thread returns -> bubble goes home
        sched.thread_returned(t)
        total = sched.queues.total_tasks()
        assert total >= 1


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------

@st.composite
def topologies(draw):
    depth = draw(st.integers(1, 3))
    fanouts = [draw(st.integers(2, 4)) for _ in range(depth)]
    levels = [Level("root", 1)] + [
        Level(f"l{i}", f, factor=1.0 + i) for i, f in enumerate(fanouts)]
    return Topology(levels)


@st.composite
def trees(draw, max_depth=3):
    def node(d):
        if d == 0 or draw(st.booleans()):
            return thread(draw(st.floats(0.5, 4.0)),
                          prio=draw(st.integers(0, 3)))
        kids = [node(d - 1) for _ in range(draw(st.integers(1, 3)))]
        return bubble(*kids, prio=draw(st.integers(0, 3)))
    root = node(max_depth)
    if not isinstance(root, type(bubble())):
        root = bubble(root)
    return root


@settings(max_examples=60, deadline=None)
@given(topo=topologies(), tree=trees())
def test_every_thread_scheduled_exactly_once(topo, tree):
    """Work conservation: driving all cpus to exhaustion schedules every
    thread exactly once and leaves no thread stranded on any queue."""
    sched = BubbleScheduler(topo)
    sched.wake_up_bubble(tree)
    want = {t.tid for t in tree.threads()}
    got = []
    idle_rounds = 0
    while idle_rounds < 2:
        progressed = False
        for cpu in range(topo.n_cpus):
            t = sched.next_thread(cpu)
            if t is not None:
                got.append(t.tid)
                t.remaining = 0.0
                progressed = True
        idle_rounds = 0 if progressed else idle_rounds + 1
    assert sorted(got) == sorted(want)
    for q in sched.queues.queues.values():
        for task in q.tasks:
            assert task.is_bubble()      # only burst husks may remain


@settings(max_examples=60, deadline=None)
@given(topo=topologies(), tree=trees())
def test_scheduling_area_respected(topo, tree):
    """A thread handed to cpu c must have been reachable from a list
    covering c (two-pass lookup soundness): trivially true if next_thread
    returns only via find/steal; assert the machinery never raises and
    stats stay consistent."""
    sched = BubbleScheduler(topo)
    sched.wake_up_bubble(tree)
    n = 0
    for _ in range(200):
        for cpu in range(topo.n_cpus):
            t = sched.next_thread(cpu)
            if t is not None:
                assert t.remaining > 0
                t.remaining = 0.0
                n += 1
    assert n == len(list(tree.threads()))
    assert sched.stats.schedules == n


@settings(max_examples=40, deadline=None)
@given(tree=trees())
def test_priorities_never_inverted_on_single_list(tree):
    """On a flat 1-cpu machine the scheduler must always return the highest
    priority runnable thread available at that moment."""
    topo = Topology([Level("root", 1), Level("cpu", 1)])
    sched = BubbleScheduler(topo)
    sched.wake_up_bubble(tree)
    last = None
    # bubbles open lazily, so priorities interleave; we assert only that
    # direct thread children available NOW at equal depth respect order
    while True:
        t = sched.next_thread(0)
        if t is None:
            break
        t.remaining = 0.0
        last = t
    assert last is not None or not list(tree.threads())
