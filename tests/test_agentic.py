"""Agentic sleep/wake lifecycle tests.

The sleep/wake layer turns a tool-calling request into the paper's
sleeping thread: at a ``tool_calls`` marker the session parks its KV via
the park/splice machinery and frees its slot (``agentic_sleep``), then
wakes on the tool response — scheduled (``think_steps``) or external
(:meth:`ServingEngine.wake`) — spliced back where the wake-affinity
quote says, without re-prefill while its KV survives.

Covered here:

* lifecycle units — the slot frees on sleep and admits backlog, the HBM
  reservation is refunded (or retained under ``sleep_retain_hbm``), a
  wake splices without touching the prefill counter, stale sessions past
  ``session_ttl`` drop their KV and re-prefill on wake, external wakes
  drain ``think_steps=None`` markers;
* wake affinity — an idle fleet always restores home; genuine backlog at
  home buys the away move; ``wake_quote=False`` pins home;
* the latency-ledger regression — TTFT stays a first-admission contract
  and think gaps never leak into inter-token percentiles (the
  double-counting ``latency_summary`` would otherwise do);
* a hypothesis property — random sleep/wake/submit traffic on 1-4 pod x
  1-4 host fleets conserves every request (no loss, no resurrection) and
  decodes streams identical to a never-sleeping run, because the stub
  stream is a pure function of token history and sleeping may only move
  tokens in time.
"""

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # clean env: seeded-sampling shim
    from _hypothesis_shim import given, settings
    from _hypothesis_shim import strategies as st

from repro.core.bubble import reset_ids
from repro.serving import (SERVE_COST, ServingEngine, SleepingLedger,
                           StubModelBackend)
from repro.serving.engine import SleepEntry

PROMPT = np.arange(1, 7, dtype=np.int32)


def make_engine(n_slots=4, group=4, hosts=1, pods=1, **kw):
    reset_ids()
    return ServingEngine(None, None, n_slots=n_slots, group=group,
                         hosts=hosts, pods=pods, backend=StubModelBackend(),
                         cost_model=SERVE_COST, **kw)


def streams(eng):
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


def run_until(eng, pred, cap=200):
    while not pred(eng):
        eng.step()
        assert eng.steps < cap, "condition never reached"


# ---------------------------------------------------------------------------
# the ledger itself
# ---------------------------------------------------------------------------

def test_sleeping_ledger_api():
    led = SleepingLedger()
    a = SleepEntry(1, None, "kv", 7, None, slept_step=2, wake_at=5)
    b = SleepEntry(2, None, "kv", 9, None, slept_step=3, wake_at=None)
    led.add(a)
    led.add(b)
    assert len(led) == 2 and 1 in led and 3 not in led
    assert led.get(2) is b and led.get(3) is None
    assert led.due(4.0) == [] and led.due(5.0) == [a]    # external: never due
    assert led.stale(4.0, ttl=2) == [a]
    b.state = None                                       # evicted: not stale
    assert led.stale(50.0, ttl=2) == [a]
    assert led.pop(1) is a and len(led) == 1
    with pytest.raises(AssertionError):
        led.add(SleepEntry(2, None, "kv", 0, None, 0, None))


# ---------------------------------------------------------------------------
# lifecycle units
# ---------------------------------------------------------------------------

def test_sleep_frees_slot_for_backlog():
    eng = make_engine(n_slots=2, group=2)
    a = eng.submit(PROMPT, 8, tool_calls=((2, 8),))
    b = eng.submit(PROMPT, 6)
    c = eng.submit(PROMPT, 6)              # no free slot until someone yields
    run_until(eng, lambda e: e.stats.sleeps == 1)
    assert a in eng._sleeping
    assert all(r is None or r.rid != a for r in eng.slot_req)
    eng.step()                             # the freed slot admits the backlog
    resident = {r.rid for r in eng.slot_req if r is not None}
    assert c in resident
    done = eng.run()
    assert sorted(r.rid for r in done) == [a, b, c]
    assert eng.stats.wakes == eng.stats.sleeps == 1


def test_sleep_refunds_hbm_reservation():
    eng = make_engine(n_slots=2, group=2, hbm_budget=2.0, kv_bytes=1.0)
    eng.submit(PROMPT, 8, tool_calls=((2, 6),))
    run_until(eng, lambda e: e.stats.sleeps == 1)
    assert sum(eng.hbm_used) == 0.0        # sleeper's bytes refunded
    eng.run()
    assert sum(eng.hbm_used) == 0.0


def test_sleep_retain_hbm_keeps_reservation():
    eng = make_engine(n_slots=2, group=2, hbm_budget=2.0, kv_bytes=1.0,
                      sleep_retain_hbm=True)
    rid = eng.submit(PROMPT, 8, tool_calls=((2, 6),))
    run_until(eng, lambda e: e.stats.sleeps == 1)
    assert sum(eng.hbm_used) == 1.0        # held for the wake
    assert eng._sleeping.get(rid).retained is not None
    eng.run()
    assert sum(eng.hbm_used) == 0.0        # released when the entry left


def test_wake_splices_without_reprefill():
    eng = make_engine()
    rid = eng.submit(PROMPT, 8, tool_calls=((3, 4),))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 8
    c = eng.counters()
    assert eng.stats.prefills == 1         # the one fresh prefill, ever
    assert c["wake_reprefills"] == 0
    assert c["sleeps"] == c["wakes"] == 1
    assert c["kv_parks"] >= 1 and c["kv_splices"] >= 1
    ref = make_engine()
    assert ref.submit(PROMPT, 8) == rid
    ref.run()
    assert streams(eng) == streams(ref)    # sleeping never changes tokens


def test_stale_session_evicted_and_reprefilled():
    eng = make_engine(session_ttl=3)
    eng.submit(PROMPT, 8, tool_calls=((2, 12),))
    done = eng.run()
    c = eng.counters()
    assert c["stale_evictions"] == 1       # KV dropped past the TTL...
    assert c["wake_reprefills"] == 1       # ...so the wake rebuilt it
    assert c["wakes"] == 1
    ref = make_engine()
    ref.submit(PROMPT, 8)
    ref.run()
    assert streams(eng) == streams(ref)
    assert len(done) == 1


def test_external_wake_drains_none_marker():
    eng = make_engine()
    rid = eng.submit(PROMPT, 6, tool_calls=((2, None),))
    run_until(eng, lambda e: e.stats.sleeps == 1)
    for _ in range(5):
        eng.step()                         # nothing schedules it...
    assert not eng._drained() and rid in eng._sleeping
    assert eng.wake(rid) is True           # ...until the client delivers
    assert eng.wake(rid) is False          # not asleep twice
    done = eng.run()
    assert [r.rid for r in done] == [rid]
    assert len(done[0].out_tokens) == 6


def test_gang_sleeps_and_wakes_together():
    eng = make_engine(n_slots=4, group=4)
    calls = ((3, 5),)
    a = eng.submit(PROMPT, 8, gang="g0", tool_calls=calls)
    b = eng.submit(PROMPT, 8, gang="g0", tool_calls=calls)
    done = eng.run()
    assert len(done) == 2
    c = eng.counters()
    assert c["sleeps"] == c["wakes"] == 2
    ref = make_engine(n_slots=4, group=4)
    ref.submit(PROMPT, 8, gang="g0")
    ref.submit(PROMPT, 8, gang="g0")
    ref.run()
    assert streams(eng) == streams(ref)
    assert a != b


# ---------------------------------------------------------------------------
# wake affinity
# ---------------------------------------------------------------------------

def test_idle_fleet_wakes_home():
    eng = make_engine(n_slots=8, group=4)  # two page groups
    eng.submit(PROMPT, 8, tool_calls=((2, 6),))
    eng.run()
    c = eng.counters()
    assert c["wake_home"] == 1 and c["wake_away"] == 0


def test_home_pressure_buys_away_wake():
    eng = make_engine(n_slots=8, group=4, hbm_budget=4.0, kv_bytes=1.0)
    eng.submit(PROMPT, 12, tool_calls=((2, 6),), home="page0")
    run_until(eng, lambda e: e.stats.sleeps == 1)
    # refill home's freed budget while the session thinks: at wake time
    # the home group is at its byte budget, the sibling is idle — the
    # quote buys the away move (page-crossing toll < waiting out home)
    for _ in range(4):
        eng.submit(PROMPT, 24, home="page0")
    eng.run(max_steps=2000)
    c = eng.counters()
    assert c["wake_away"] == 1 and c["wake_home"] == 0
    assert len(eng.completed) == 5


def test_wake_quote_off_pins_home():
    eng = make_engine(n_slots=8, group=4, hbm_budget=4.0, kv_bytes=1.0,
                      wake_quote=False)
    eng.submit(PROMPT, 12, tool_calls=((2, 6),), home="page0")
    run_until(eng, lambda e: e.stats.sleeps == 1)
    for _ in range(4):
        eng.submit(PROMPT, 24, home="page0")
    eng.run(max_steps=2000)
    c = eng.counters()
    assert c["wake_home"] == 1 and c["wake_away"] == 0


# ---------------------------------------------------------------------------
# the latency-ledger regression: one request, many service intervals
# ---------------------------------------------------------------------------

def test_ttft_judged_on_first_admission_only():
    eng = make_engine()
    eng.submit(PROMPT, 8, sla="standard", tool_calls=((2, 9),))
    eng.run()
    ref = make_engine()
    ref.submit(PROMPT, 8, sla="standard")
    ref.run()
    lat = eng.latency_summary()["classes"]["standard"]
    ref_lat = ref.latency_summary()["classes"]["standard"]
    assert lat["n"] == 1                   # one TTFT sample, not one per wake
    assert lat["ttft_p99"] == ref_lat["ttft_p99"]      # first admission only
    assert lat["wakes"] == 1 and lat["wake_p99"] < 9   # wake ledger separate
    # the 9-step think gap must not leak into inter-token percentiles —
    # the double-counting this ledger would otherwise do
    assert lat["tok_p99"] <= ref_lat["tok_p99"] + 1


def test_wake_latency_counts_requeue_wait():
    eng = make_engine(n_slots=2, group=2)
    eng.submit(PROMPT, 8, tool_calls=((2, 2),))
    for _ in range(4):                     # contention: the wake must queue
        eng.submit(PROMPT, 10)
    eng.run(max_steps=2000)
    lat = eng.latency_summary()["classes"]["unclassed"]
    assert lat["wakes"] == 1
    assert lat["wake_p99"] >= 1.0          # waited for a slot after waking


# ---------------------------------------------------------------------------
# property: random sleep/wake/submit traffic conserves every request
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(pods=st.integers(min_value=1, max_value=4),
       hosts=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=10 ** 6))
def test_random_traffic_conserved_and_stream_identical(pods, hosts, seed):
    rng = np.random.default_rng(seed)
    n_slots = pods * hosts * 4
    arrivals = []                          # (step, prompt, new, calls, gang)
    for i in range(int(rng.integers(3, 13))):
        new = int(rng.integers(2, 12))
        calls, at = [], 1
        while at < new and rng.random() < 0.55:
            think = None if rng.random() < 0.3 else int(rng.integers(1, 9))
            calls.append((at, think))
            at += int(rng.integers(1, 4))
        gang = f"g{i // 3}" if rng.random() < 0.3 else None
        arrivals.append((int(rng.integers(0, 10)),
                         rng.integers(1, 97, int(rng.integers(2, 8))),
                         new, tuple(calls), gang))
    arrivals.sort(key=lambda a: a[0])

    def drive_arm(strip_calls):
        eng = make_engine(n_slots=n_slots, group=2, hosts=hosts, pods=pods)
        rids, i = [], 0
        while i < len(arrivals) or not eng._drained():
            now = eng.steps
            while i < len(arrivals) and arrivals[i][0] <= now:
                step, prompt, new, calls, gang = arrivals[i]
                i += 1
                rids.append(eng.submit(
                    prompt, new, gang=gang,
                    tool_calls=() if strip_calls else calls))
            if not strip_calls:
                # deliver tool responses for externally-blocked sessions:
                # randomly while young, unconditionally past a deadline
                for e in eng._sleeping.entries():
                    if e.wake_at is None and (now > 60
                                              or rng.random() < 0.4):
                        assert eng.wake(e.rid)
            eng.step()
            assert eng.steps < 3000, "traffic did not drain"
        return eng, rids

    agentic, rids = drive_arm(strip_calls=False)
    never, ref_rids = drive_arm(strip_calls=True)
    assert rids == ref_rids                # same submission order, same ids
    got = streams(agentic)
    # conservation: every request completes exactly once — no loss on the
    # sleep path, no resurrection from the ledger
    assert sorted(got) == sorted(rids)
    assert len(agentic.completed) == len(rids)
    # sleeping moves tokens in time, never changes them
    assert got == streams(never)
