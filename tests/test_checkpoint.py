"""Checkpoint-store tests: the parameter store (``checkpoint.store``) and
the decode-continuation store (``checkpoint.kv_store``).

Both share one on-disk discipline — ``.tmp_step_*`` dir + ``os.replace``,
``manifest.json`` marking completeness, bfloat16 leaves stored as a uint16
view with the true dtype in the manifest — so both are pinned here: the
round trip (exact bits back, bf16 included), ``latest_step`` ignoring
in-flight tmp dirs and manifest-less wrecks, and mid-write-crash atomicity
(a crash before the rename must leave the previous complete step
restorable and the torn write invisible).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint import kv_store


def tree():
    return {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "scale": np.float64(0.5),
            "emb": {"table": np.arange(6, dtype=np.int32)}}


class TestStoreRoundTrip:
    def test_roundtrip_exact(self, tmp_path):
        t = tree()
        ckpt.save(tmp_path, 7, t)
        got, manifest = ckpt.restore(tmp_path, 7, t)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(got["w"]), t["w"])
        np.testing.assert_array_equal(np.asarray(got["emb"]["table"]),
                                      t["emb"]["table"])

    def test_bfloat16_uint16_view_roundtrip(self, tmp_path):
        """bf16 cannot be np.save'd natively; the store writes the uint16
        bit view and the manifest keeps the true dtype.  The bits — not a
        rounded float32 detour — must come back."""
        t = {"p": jnp.arange(16, dtype=jnp.bfloat16) / 7}
        ckpt.save(tmp_path, 1, t)
        on_disk = np.load(tmp_path / "step_00000001" / "p.npy")
        assert on_disk.dtype == np.uint16
        manifest = json.loads(
            (tmp_path / "step_00000001" / "manifest.json").read_text())
        assert manifest["leaves"]["p"]["dtype"] == "bfloat16"
        got, _ = ckpt.restore(tmp_path, 1, t)
        assert got["p"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(got["p"]).view(np.uint16),
            np.asarray(t["p"]).view(np.uint16))

    def test_manifest_extra(self, tmp_path):
        ckpt.save(tmp_path, 3, tree(), extra={"lr": 0.1})
        assert ckpt.manifest_extra(tmp_path, 3) == {"lr": 0.1}


class TestLatestStep:
    def test_ignores_orphaned_tmp_dirs(self, tmp_path):
        """A crash between mkdir and rename leaves a ``.tmp_step_*`` husk;
        it must never be reported as the latest checkpoint, even when its
        step number is newest and it contains a manifest."""
        ckpt.save(tmp_path, 5, tree())
        wreck = tmp_path / ".tmp_step_00000009"
        wreck.mkdir()
        (wreck / "manifest.json").write_text("{}")
        assert ckpt.latest_step(tmp_path) == 5
        assert kv_store.latest_step(tmp_path) == 5

    def test_ignores_manifestless_dir(self, tmp_path):
        ckpt.save(tmp_path, 5, tree())
        (tmp_path / "step_00000009").mkdir()     # renamed but torn: no manifest
        assert ckpt.latest_step(tmp_path) == 5

    def test_empty_and_missing(self, tmp_path):
        assert ckpt.latest_step(tmp_path / "nope") is None
        assert ckpt.latest_step(tmp_path) is None


class TestAtomicity:
    def test_crash_before_rename_keeps_previous_step(self, tmp_path,
                                                     monkeypatch):
        """Kill the writer at the worst moment — everything written, rename
        not yet executed — and the store must still restore step 1 bit-for-
        bit, with the torn step 2 invisible to ``latest_step``."""
        t = tree()
        ckpt.save(tmp_path, 1, t)
        real_replace = os.replace

        def crash(src, dst):
            raise OSError("simulated crash mid-rename")

        monkeypatch.setattr(os, "replace", crash)
        with pytest.raises(OSError):
            ckpt.save(tmp_path, 2, {"w": t["w"] * 2, "scale": t["scale"],
                                    "emb": t["emb"]})
        monkeypatch.setattr(os, "replace", real_replace)
        assert ckpt.latest_step(tmp_path) == 1
        got, _ = ckpt.restore(tmp_path, 1, t)
        np.testing.assert_array_equal(np.asarray(got["w"]), t["w"])
        # the interrupted step retries cleanly over its own husk
        ckpt.save(tmp_path, 2, t)
        assert ckpt.latest_step(tmp_path) == 2


class TestKVStore:
    def entries(self, k=2):
        return {rid: (([np.arange(4) + rid], {"pos": np.int64(3 + rid)}),
                      7 + rid, 2 + rid) for rid in range(k)}

    def test_roundtrip_nested_pytree(self, tmp_path):
        store = kv_store.KVStore(tmp_path, cadence=1)
        store.snapshot(10, self.entries())
        got = store.restore()
        assert set(got) == {0, 1}
        snap = got[1]
        assert (snap.tok, snap.emitted) == (8, 3)
        state_list, state_dict = snap.state
        np.testing.assert_array_equal(state_list[0], np.arange(4) + 1)
        assert int(state_dict["pos"]) == 4

    def test_cadence(self, tmp_path):
        store = kv_store.KVStore(tmp_path, cadence=4)
        assert store.maybe_snapshot(0, self.entries())
        assert not store.maybe_snapshot(3, self.entries())
        assert store.maybe_snapshot(4, self.entries())
        assert store.latest() == 4

    def test_crash_mid_write_keeps_previous(self, tmp_path, monkeypatch):
        store = kv_store.KVStore(tmp_path, cadence=1)
        store.snapshot(1, self.entries())
        monkeypatch.setattr(os, "replace",
                            lambda s, d: (_ for _ in ()).throw(OSError()))
        with pytest.raises(OSError):
            store.snapshot(2, self.entries())
        monkeypatch.undo()
        assert store.latest() == 1
        assert set(store.restore()) == {0, 1}

    def test_empty_store_restores_nothing(self, tmp_path):
        store = kv_store.KVStore(tmp_path)
        assert store.restore() == {}
        assert store.latest() is None
