"""Hierarchical work-stealing + next-touch migration engine tests.

Covers the §3.3.3 steal pass: conservation (no task lost or duplicated
across steal/regenerate cycles), the affinity invariant (loot comes from
the closest level that had any, whole bubbles preferred, and lands inside
the thief's covering chain), `SchedStats` counter correctness, the
identity-safe run-queue removal the steal path depends on, and the
simulator's next-touch data migration.
"""

import pytest

from repro.core import (THRASH_COST, ZERO_COST, AdaptivePolicy, BubblePolicy,
                        BubbleScheduler, Level, QueueHierarchy, SimplePolicy,
                        Simulator, StealCostModel, StealPolicy, Topology,
                        bubble, imbalanced_stripes_workload, novascale_16,
                        reset_ids, stripes_workload, thrash_stripes_workload,
                        thread)
from repro.core.runqueues import RunQueue
from repro.core.trace import Tracer


# ---------------------------------------------------------------------------
# run-queue removal: identity, not equality (regression)
# ---------------------------------------------------------------------------

class TestRunQueueIdentity:
    def _queue(self):
        topo = Topology([Level("root", 1), Level("cpu", 1)])
        return QueueHierarchy(topo).global_queue()

    def test_remove_twin_is_identity_safe(self):
        """Two structurally-identical threads: removing the second must not
        delete the first (the old equality-based removal pulled whichever
        twin sat closest to the head)."""
        q = self._queue()
        a = thread(1.0, name="twin")
        b = thread(1.0, name="twin")
        q.push(a)
        q.push(b)
        assert q.remove(b)
        assert len(q) == 1 and q.tasks[0] is a

    def test_pop_best_claims_exact_object_at_non_head(self):
        q = self._queue()
        lo = thread(1.0, name="lo", prio=0)
        hi1 = thread(1.0, name="hi", prio=5)
        hi2 = thread(1.0, name="hi", prio=5)
        for t in (lo, hi1, hi2):
            q.push(t)
        got = q.pop_best()
        assert got is hi1                       # FIFO among equals
        assert list(q.tasks) == [lo, hi2]
        assert q.tasks[1] is hi2                # hi2 untouched, not a copy

    def test_remove_missing_returns_false(self):
        q = self._queue()
        q.push(thread(1.0))
        assert not q.remove(thread(1.0))
        assert len(q) == 1

    def test_version_bumped_on_removal(self):
        q = self._queue()
        t = thread(1.0)
        q.push(t)
        v = q.version
        q.remove(t)
        assert q.version > v                    # pass-2 revalidation sees it


# ---------------------------------------------------------------------------
# the steal pass itself
# ---------------------------------------------------------------------------

class TestStealPass:
    def test_steals_whole_bubble_over_thread(self):
        """At one level, a closed bubble beats any lone thread — moving the
        coherent group keeps its internal affinity intact."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        node1 = topo.components("node")[1]
        fat = thread(50.0, name="fat")
        grp = bubble(thread(2.0), thread(2.0), name="grp")
        sched.queues.queue_of(node1).push(fat)
        sched.queues.queue_of(node1).push(grp)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is grp
        assert sched.stats.bubble_steals == 1
        assert sched.stats.thread_steals == 0

    def test_closest_level_wins_over_heavier_loot(self):
        """A small thread on a sibling cpu queue (same node) is preferred
        over a big bubble a node away: most-local victim first."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        near = thread(1.0, name="near")
        sched.queues.covering(3)[0].push(near)        # cpu3: node0 sibling
        far = bubble(*[thread(9.0) for _ in range(4)], name="far")
        sched.queues.queue_of(topo.components("node")[2]).push(far)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is near

    def test_stolen_threads_are_marked_for_next_touch(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(thread(2.0), thread(2.0), name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        _, loot = sched._steal_pass(0)
        assert loot is grp
        assert all(t.stolen for t in grp.threads())

    def test_placement_lands_in_thief_covering_chain(self):
        """The affinity invariant: loot is re-pushed onto the nearest list
        of the thief wide enough to hold it."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(*[thread(2.0) for _ in range(4)], name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        victim, loot = sched._steal_pass(0)
        sched._place_near(loot, 0)
        chain = sched.queues.covering(0)
        holder = [q for q in chain if loot in q.tasks]
        assert holder, "stolen bubble must sit on a queue covering the thief"
        # width 4 fits exactly at node level — not dumped on the global list
        assert holder[0].level == "node"
        assert victim.comp.name == "node3"

    def test_steal_respects_disable_flag(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo, steal=False)
        grp = bubble(thread(2.0), name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        assert sched.next_thread(0) is None
        assert sched.stats.steals == 0
        # the loot is untouched on its home queue
        assert grp in sched.queues.queue_of(topo.components("node")[3]).tasks

    def test_steal_counters_add_up(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        sched.queues.queue_of(topo.components("node")[1]).push(
            bubble(thread(2.0), name="g1"))
        sched.queues.queue_of(topo.components("node")[2]).push(
            thread(3.0, name="solo"))
        assert sched._steal_pass(0) is not None
        assert sched._steal_pass(0) is not None
        assert sched._steal_pass(0) is None            # nothing left
        s = sched.stats
        assert s.steals == 2
        assert s.steals == s.bubble_steals + s.thread_steals
        assert s.steal_attempts == 3
        assert s.stolen_work == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# cost-aware victim selection (work-per-cost ranking under a nonzero model)
# ---------------------------------------------------------------------------

class TestCostAwareVictimSelection:
    CM = StealCostModel(lock_penalty=1.0, level_penalty=4.0,
                        thread_penalty=1.0)

    def test_near_lighter_bubble_beats_far_heavier(self):
        """The ROADMAP case: under a cost model, a nearer, slightly
        lighter bubble is the better steal — raw heaviest-loot ranking
        would take the heavier bubble two levels out and pay double the
        level penalty."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        near = bubble(thread(4.5), thread(4.5), name="near")     # work 9
        far = bubble(thread(6.0), thread(6.0), name="far")       # work 12
        sched.queues.queue_of(topo.cpus[1]).push(near)   # sibling cpu: dist 1
        sched.queues.queue_of(topo.components("node")[3]).push(far)  # dist 2
        # scores: near 9/(1+4+2)=1.29 > far 12/(1+8+2)=1.09
        got = sched._steal_pass(0)
        assert got is not None and got[1] is near
        assert sched.stats.last_steal_distance == 1

    def test_fewer_threads_to_drag_wins_at_same_level(self):
        """Same distance, same-ish work: the bubble dragging fewer live
        threads has the better work-per-cost."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        many = bubble(*[thread(1.25) for _ in range(8)], name="many")  # w 10
        few = bubble(thread(4.5), thread(4.5), name="few")             # w 9
        q = sched.queues.queue_of(topo.components("node")[1])
        q.push(many)
        q.push(few)
        # scores: many 10/(1+8+8)=0.59 < few 9/(1+8+2)=0.82
        got = sched._steal_pass(0)
        assert got is not None and got[1] is few

    def test_far_worthwhile_bubble_beats_near_scrap_thread(self):
        """The costed pass surveys *all* covering levels: a big affinity
        group two levels out can out-score a near lone thread — the free
        path would have stopped at the first level with any candidate."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        scrap = thread(2.0, name="scrap")
        sched.queues.queue_of(topo.cpus[1]).push(scrap)
        grp = bubble(*[thread(20.0) for _ in range(2)], name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is grp

    def test_zero_cost_keeps_heaviest_per_level(self):
        """Control: with free steals the historical selection is intact —
        closest level first, heaviest loot within it (the golden traces
        additionally pin this end-to-end)."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)                    # ZERO_COST
        near = bubble(thread(4.5), thread(4.5), name="near")
        far = bubble(thread(6.0), thread(6.0), name="far")
        sched.queues.queue_of(topo.cpus[1]).push(near)
        sched.queues.queue_of(topo.components("node")[3]).push(far)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is near        # same pick here
        sched2 = BubbleScheduler(topo)
        scrap = thread(2.0, name="scrap")
        sched2.queues.queue_of(topo.cpus[1]).push(scrap)
        grp = bubble(*[thread(20.0) for _ in range(2)], name="grp")
        sched2.queues.queue_of(topo.components("node")[3]).push(grp)
        got2 = sched2._steal_pass(0)
        assert got2 is not None and got2[1] is scrap     # closest level wins

    def test_distance_histogram_filled(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.CM)
        sched.queues.queue_of(topo.cpus[1]).push(thread(1.0))
        sched.queues.queue_of(topo.components("node")[3]).push(thread(9.0))
        sched._steal_pass(0)
        sched._steal_pass(0)
        assert sched.stats.steal_distance_hist == {1: 1, 2: 1}


# ---------------------------------------------------------------------------
# per-level penalty table + the decision/bill split (multi-host pricing)
# ---------------------------------------------------------------------------

class TestLevelTableAndBilling:
    TABLE = StealCostModel(lock_penalty=1.0, level_penalty=0.5,
                           thread_penalty=0.25,
                           level_table=(("node", 10.0),))

    def test_level_cost_lookup_and_fallback(self):
        assert self.TABLE.level_cost("node") == 10.0
        assert self.TABLE.level_cost("cpu") == 0.5       # fallback
        assert self.TABLE.level_cost(None) == 0.5
        assert self.TABLE.steal_cost(2, 1, "node") == \
            pytest.approx(1.0 + 20.0 + 0.25)
        assert self.TABLE.steal_cost(2, 1) == pytest.approx(1.0 + 1.0 + 0.25)

    def test_table_alone_makes_steals_costed(self):
        """A model whose only nonzero price sits in the table must still
        switch victim selection to the costed survey."""
        cm = StealCostModel(level_table=(("node", 5.0),))
        assert not cm.steals_are_free
        assert ZERO_COST.steals_are_free

    def test_boundary_priced_steal_billed(self):
        """Stealing across a NUMA node bills the table's per-level price;
        a sibling-cpu steal keeps the uniform fallback."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        sched.queues.queue_of(topo.components("node")[3]).push(thread(9.0))
        got = sched._steal_pass(0)                       # crosses "node"
        assert got is not None
        assert sched.stats.last_steal_cost == \
            pytest.approx(1.0 + 10.0 * 2 + 0.25)
        sched2 = BubbleScheduler(topo, cost_model=self.TABLE)
        sched2.queues.queue_of(topo.cpus[1]).push(thread(9.0))
        got2 = sched2._steal_pass(0)                     # sibling cpu
        assert got2 is not None
        assert sched2.stats.last_steal_cost == \
            pytest.approx(1.0 + 0.5 + 0.25)

    def test_bill_model_splits_belief_from_charge(self):
        """A mispriced scheduler: victim selection consults ``cost_model``
        (flat) while the ledger bills ``bill_model`` (the table) — the
        DCN-naive serving baseline in unit form."""
        flat = StealCostModel(lock_penalty=1.0, level_penalty=0.5,
                              thread_penalty=0.25)
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=flat, bill_model=self.TABLE)
        near = thread(4.0, name="near")
        far = thread(9.0, name="far")
        sched.queues.queue_of(topo.cpus[1]).push(near)
        sched.queues.queue_of(topo.components("node")[3]).push(far)
        got = sched._steal_pass(0)
        # flat belief: far 9/(1+1+.25)=4.0 beats near 4/(1+.5+.25)=2.3 ...
        assert got is not None and got[1] is far
        # ... but the machine charges the node crossing at table prices
        assert sched.stats.last_steal_cost == \
            pytest.approx(1.0 + 10.0 * 2 + 0.25)
        assert sched.consume_cost() == pytest.approx(1.0 + 10.0 * 2 + 0.25)

    def test_capacity_callback_refuses_and_accounts(self):
        """A vetoing capacity callback makes the survey skip the loot and
        book the refusal."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        sched.capacity_cb = lambda cpu, task, pending: False
        sched.queues.queue_of(topo.components("node")[3]).push(thread(9.0))
        assert sched._steal_pass(0) is None
        assert sched.stats.steal_refusals == 1
        assert sched.stats.steals == 0
        sched.capacity_cb = None
        assert sched._steal_pass(0) is not None

    def test_rebalance_deals_only_where_capacity_allows(self):
        """The bulk re-spread respects the same veto: units land on the
        accepting components only; units nothing accepts fall back to the
        global list instead of flooding a full destination."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        allowed = {c.cpu for c in topo.components("node")[1].leaves()}
        sched.capacity_cb = lambda cpu, task, pending=(): cpu in allowed
        for _ in range(6):
            sched.queues.global_queue().push(thread(3.0))
        assert sched.rebalance(0, level="node") == 6
        q1 = sched.queues.queue_of(topo.components("node")[1])
        assert len(q1) == 6                   # every unit on the accepter
        # nothing accepts: the units go back to the global list
        sched2 = BubbleScheduler(topo, cost_model=self.TABLE)
        sched2.capacity_cb = lambda cpu, task, pending=(): False
        for _ in range(4):
            sched2.queues.global_queue().push(thread(3.0))
        assert sched2.rebalance(0, level="node") == 4
        assert len(sched2.queues.global_queue()) == 4
        assert sched2.stats.steal_refusals == 4

    def test_rebalance_deal_counts_its_own_pending_routing(self):
        """One bulk deal must not overcommit a destination that had room
        for a single unit: the veto sees the tasks already routed there
        within the same deal (the consumer's ledger only reserves at
        claim time)."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        node1 = {c.cpu for c in topo.components("node")[1].leaves()}

        def one_seat(cpu, task, pending=()):
            return cpu in node1 and len(pending) < 1
        sched.capacity_cb = one_seat
        for _ in range(5):
            sched.queues.global_queue().push(thread(3.0))
        assert sched.rebalance(0, level="node") == 5
        q1 = sched.queues.queue_of(topo.components("node")[1])
        assert len(q1) == 1                  # exactly the seat it had
        assert len(sched.queues.global_queue()) == 4   # overflow widened
        assert sched.stats.steal_refusals == 4

    def test_table_only_model_free_boundary_does_not_crash(self):
        """Regression: a model whose only nonzero penalty is in the table
        leaves un-tabled boundaries at cost 0 — the costed survey must
        score that loot as infinitely cheap, not divide by zero."""
        cm = StealCostModel(level_table=(("node", 5.0),))
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=cm)
        near = thread(2.0, name="near")               # sibling cpu: cost 0
        far = thread(9.0, name="far")                 # node crossing: 10
        sched.queues.queue_of(topo.cpus[1]).push(near)
        sched.queues.queue_of(topo.components("node")[3]).push(far)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is near     # free beats priced
        assert sched.stats.last_steal_cost == 0.0


# ---------------------------------------------------------------------------
# DCN-priced + scoped rebalancing (per-move boundary billing, host-local
# mode, the exact quote)
# ---------------------------------------------------------------------------

class TestScopedAndPricedRebalance:
    TABLE = StealCostModel(rebalance_base=1.0, rebalance_per_move=0.5,
                           level_table=(("node", 10.0),))

    def test_move_cost_is_table_only(self):
        """Rebalance moves have NO level_penalty fallback: un-tabled (and
        un-crossed) boundaries price to the flat per-move cost, so every
        pre-table bill is reproduced exactly."""
        cm = StealCostModel(level_penalty=7.0, rebalance_per_move=0.5,
                            level_table=(("node", 10.0),))
        assert cm.rebalance_move_cost("node") == pytest.approx(10.5)
        assert cm.rebalance_move_cost("cpu") == pytest.approx(0.5)
        assert cm.rebalance_move_cost(None) == pytest.approx(0.5)

    def test_moves_priced_by_boundary_crossed(self):
        """4 equal units gathered from node3's list and LPT-dealt across
        the 4 nodes: the 3 that leave node3 pay the table's toll, the one
        that stays pays flat.  Without an ingest-billing consumer the
        triggering cpu pays the WHOLE bill through consume_cost() —
        billed == accrued holds for the simulator path even under a
        tabled model."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        for _ in range(4):
            sched.queues.queue_of(topo.components("node")[3]).push(
                thread(3.0))
        moves = sched.rebalance(0, level="node")
        assert moves == 4
        assert sched.stats.rebalance_cost == \
            pytest.approx(1.0 + 4 * 0.5 + 3 * 10.0)
        assert sched.consume_cost() == pytest.approx(sched.stats.rebalance_cost)
        ingest = sched.stats.last_rebalance_ingest
        assert sum(ingest.values()) == pytest.approx(3 * 10.0)
        assert set(ingest) == {"node0", "node1", "node2"}

    def test_ingest_billing_splits_the_bill(self):
        """An ingest-billing consumer (the serving engine) gets the flat
        trigger-side part from consume_cost() and bills the tolls where
        the data lands; flat part + ingest == the full accrued cost, so
        nothing is double-billed or dropped."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        sched.ingest_billing = True
        for _ in range(4):
            sched.queues.queue_of(topo.components("node")[3]).push(
                thread(3.0))
        sched.rebalance(0, level="node")
        flat = sched.consume_cost()
        assert flat == pytest.approx(1.0 + 4 * 0.5)
        assert flat + sum(sched.stats.last_rebalance_ingest.values()) == \
            pytest.approx(sched.stats.rebalance_cost)

    def test_scope_restricts_gather_and_deal(self):
        """A node-scoped re-spread touches only that node's subtree: work
        outside the scope stays put, every unit lands inside the scope,
        and no move crosses a tabled boundary (ingest empty)."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        n0 = topo.components("node")[0]
        for c in n0.children:
            sched.queues.queue_of(c).push(thread(2.0))
        outside = thread(9.0)
        sched.queues.queue_of(topo.components("node")[1]).push(outside)
        moves = sched.rebalance(0, level="cpu", scope="node0")
        assert moves == 4
        q1 = sched.queues.queue_of(topo.components("node")[1])
        assert outside in q1.tasks                     # untouched
        inside = [t for c in n0.children
                  for t in sched.queues.queue_of(c).tasks]
        assert len(inside) == 4                        # dealt inside scope
        assert sched.stats.last_rebalance_ingest == {}
        assert sched.consume_cost() == pytest.approx(1.0 + 4 * 0.5)

    def test_estimate_is_the_bill(self):
        """The quote replays the deal: estimate_rebalance returns exactly
        the moves and cost the committed rebalance then bills (cost and
        bill model being the same here)."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        for i, node in enumerate((0, 0, 2, 3)):
            sched.queues.queue_of(topo.components("node")[node]).push(
                thread(2.0 + i))
        sched.queues.global_queue().push(thread(7.0))
        movable, quote = sched.estimate_rebalance("node")
        moves = sched.rebalance(0, level="node")
        assert moves == movable == 5
        assert sched.stats.last_rebalance_cost == pytest.approx(quote)

    def test_flat_model_quote_degenerates_to_flat_cost(self):
        """Table-free models: the exact quote equals the historical flat
        estimate, so flat consumers keep bit-identical trigger
        decisions."""
        cm = StealCostModel(rebalance_base=2.0, rebalance_per_move=0.5)
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=cm)
        for _ in range(6):
            sched.queues.global_queue().push(thread(1.0))
        movable, quote = sched.estimate_rebalance("node")
        assert movable == 6
        assert quote == pytest.approx(cm.rebalance_cost(6))

    def test_estimate_touches_no_queue(self):
        """Quoting is free: the queues are bit-identical before and
        after."""
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=self.TABLE)
        for node in (0, 1, 3):
            sched.queues.queue_of(topo.components("node")[node]).push(
                thread(4.0))
        before = {q.comp.name: list(q.tasks)
                  for q in sched.queues.queues.values()}
        sched.estimate_rebalance("node")
        sched.estimate_rebalance("node", scope="node0")
        after = {q.comp.name: list(q.tasks)
                 for q in sched.queues.queues.values()}
        assert before == after
        assert sched.stats.rebalances == 0


# ---------------------------------------------------------------------------
# adaptive rebalance level (derived from the steal-distance histogram)
# ---------------------------------------------------------------------------

class TestAdaptiveRebalanceLevel:
    def test_explicit_level_always_wins(self):
        sched = BubbleScheduler(novascale_16())
        sched.stats.steal_distance_hist = {1: 100}
        assert sched._resolve_spread_level("machine") == "machine"

    def test_no_observations_falls_back_to_default(self):
        sched = BubbleScheduler(novascale_16())
        assert sched._resolve_spread_level(None) == "node"

    def test_modal_distance_picks_matching_level(self):
        sched = BubbleScheduler(novascale_16())
        sched.stats.steal_distance_hist = {2: 5, 1: 2}   # cross-node mode
        assert sched._resolve_spread_level(None) == "node"
        sched.stats.steal_distance_hist = {1: 5, 2: 2}   # sibling-cpu mode
        assert sched._resolve_spread_level(None) == "cpu"
        sched.stats.steal_distance_hist = {1: 3, 2: 3}   # tie: wider wins
        assert sched._resolve_spread_level(None) == "node"

    def test_sibling_churn_respreads_at_cpu_level(self):
        """End-to-end: steals observed only at distance 1 make a
        level=None rebalance deal across the per-cpu lists."""
        topo = novascale_16()
        sched = BubbleScheduler(topo,
                                cost_model=StealCostModel(lock_penalty=1.0))
        for i in range(3):
            sched.queues.queue_of(topo.cpus[1]).push(thread(5.0))
        sched._steal_pass(0)                              # distance-1 steal
        assert sched.stats.steal_distance_hist == {1: 1}
        sched.rebalance(0)
        cpu_qs = [len(sched.queues.queue_of(c)) for c in topo.cpus]
        assert sum(cpu_qs) == 2                  # both queued tasks re-dealt
        assert max(cpu_qs) == 1                  # ...across per-cpu lists

    def test_thrash_workload_derives_node_and_still_wins(self):
        """On the thrash tree the steal traffic is cross-node (modal
        distance 2): the derived spread level is ``node``, rebalances
        fire, and adaptive still beats costed steal (the PR 2 acceptance
        preserved under the adaptive knob)."""
        r_steal, ps = _sim(StealPolicy, thrash_stripes_workload,
                           cost_model=THRASH_COST)
        r_adapt, pa = _sim(AdaptivePolicy, thrash_stripes_workload,
                           cost_model=THRASH_COST)
        hist = pa.sched.stats.steal_distance_hist
        assert max(hist, key=lambda k: (hist[k], k)) == 2
        assert pa.sched._resolve_spread_level(None) == "node"
        assert pa.sched.stats.rebalances > 0
        assert r_adapt.time < r_steal.time


# ---------------------------------------------------------------------------
# conservation + integration through next_thread
# ---------------------------------------------------------------------------

def _drive_to_exhaustion(sched, topo):
    got = []
    idle_rounds = 0
    while idle_rounds < 2:
        progressed = False
        for cpu in range(topo.n_cpus):
            t = sched.next_thread(cpu)
            if t is not None:
                got.append(t)
                t.remaining = 0.0
                progressed = True
        idle_rounds = 0 if progressed else idle_rounds + 1
    return got


class TestConservation:
    def test_unbalanced_tree_schedules_every_thread_once(self):
        """All work sits under one node; the other three must steal.  No
        thread may be lost or scheduled twice."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        root = bubble(*[bubble(*[thread(1.0) for _ in range(4)],
                               name=f"g{i}", burst_level="node")
                        for i in range(8)], name="app")
        node0 = topo.components("node")[0]
        sched.wake_up_bubble(root, at=sched.queues.queue_of(node0))
        got = _drive_to_exhaustion(sched, topo)
        want = list(root.threads())
        assert sorted(t.tid for t in got) == sorted(t.tid for t in want)
        assert sched.stats.steals > 0
        for q in sched.queues.queues.values():
            for task in q.tasks:
                assert task.is_bubble()       # only burst husks may remain

    def test_steal_then_regenerate_conserves(self):
        """Steal a bubble, burst it remotely, regenerate it — nothing is
        lost or duplicated across the cycle."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(*[thread(5.0) for _ in range(4)], name="grp")
        node3 = topo.components("node")[3]
        sched.wake_up_bubble(grp, at=sched.queues.queue_of(node3))
        t = sched.next_thread(0)               # cpu0 steals + bursts locally
        assert t is not None and sched.stats.steals == 1
        sched.regenerate(grp, running={0: t})
        sched.thread_returned(t)
        # every thread is back inside the (single) closed bubble on a queue
        assert sched.queues.total_tasks() == 1
        assert not grp.burst
        remaining = {id(x) for x in grp.threads()}
        assert len(remaining) == 4


class TestCountersAndTrace:
    def test_trace_records_steal_victim_level(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        tracer = Tracer(sched)
        grp = bubble(*[thread(2.0) for _ in range(4)], name="grp")
        sched.wake_up_bubble(grp, at=sched.queues.queue_of(
            topo.components("node")[2]))
        t = sched.next_thread(0)
        assert t is not None
        steals = tracer.steals()
        assert len(steals) == sched.stats.steals == 1
        assert steals[0].task == "grp"
        assert steals[0].level == "node"

    def test_migration_counter_counts_cpu_changes(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        t = thread(2.0, name="mover")
        sched.submit_thread(t)
        assert sched.next_thread(3) is t
        sched.queues.global_queue().push(t)
        assert sched.next_thread(9) is t
        assert sched.stats.migrations == 1


# ---------------------------------------------------------------------------
# next-touch data migration (simulator side)
# ---------------------------------------------------------------------------

def _sim(policy_cls, root_fn, mem=0.25, cycles=8, **kw):
    reset_ids()
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    root = root_fn()
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=cycles), pol


class TestNextTouch:
    def test_steal_policy_selects_next_touch(self):
        topo = novascale_16()
        sim = Simulator(topo, StealPolicy(topo))
        assert sim.data_policy == "next_touch"
        sim2 = Simulator(topo, StealPolicy(topo), data_policy="first_touch")
        assert sim2.data_policy == "first_touch"       # explicit arg wins
        assert Simulator(topo, BubblePolicy(topo)).data_policy == "first_touch"

    def test_stolen_work_rehomes_on_next_touch(self):
        r, pol = _sim(StealPolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0
        assert r.data_migrations > 0
        assert r.extra["data_policy"] == "next_touch"

    def test_first_touch_never_migrates_data(self):
        r, pol = _sim(BubblePolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0              # stealing happened...
        assert r.data_migrations == 0                  # ...but data stayed put

    def test_rehome_updates_home_map(self):
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol)
        t = thread(4.0, data="page")
        sim.homes["page"] = 12                         # homed on node3
        t.stolen = True
        assert sim._speed(0, t) == 1.0                 # migrating touch
        assert sim.homes["page"] == 0                  # re-homed under thief
        assert sim.data_migrations == 1
        assert not t.stolen                            # flag is one-shot
        assert sim._speed(0, t) == 1.0                 # now local for real

    def test_result_counters_are_per_run_deltas(self):
        """A reused Simulator must report each run's own steal/migration
        counts, not lifetime cumulatives (regression)."""
        reset_ids()
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                        contention=0.5)
        r1 = sim.run(imbalanced_stripes_workload(), cycles=3)
        r2 = sim.run(imbalanced_stripes_workload(), cycles=3)
        assert r1.extra["steals"] > 0
        assert r1.extra["steals"] + r2.extra["steals"] == \
            pol.sched.stats.steals
        assert r1.data_migrations + r2.data_migrations == sim.data_migrations

    def test_migration_cost_charged_on_moving_touch(self):
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol, migration_cost=1.0)
        t = thread(4.0, data="page")
        sim.homes["page"] = 12
        t.stolen = True
        assert sim._speed(0, t) == pytest.approx(0.5)  # pays the move once
        assert sim._speed(0, t) == 1.0


# ---------------------------------------------------------------------------
# steal-cost accounting (StealCostModel)
# ---------------------------------------------------------------------------

# a handful of penalty corners: zero, lock-only, level-only, thread-only, mixed
COST_GRID = [
    StealCostModel(),
    StealCostModel(lock_penalty=1.0),
    StealCostModel(level_penalty=2.0),
    StealCostModel(thread_penalty=0.5),
    StealCostModel(lock_penalty=2.0, level_penalty=4.0, thread_penalty=1.0),
]


class TestStealCostAccounting:
    def test_levels_crossed_distances(self):
        topo = novascale_16()
        node = topo.components("node")
        # a covering list is free; a sibling cpu is 1 level; across nodes, 2
        assert topo.levels_crossed(0, node[0]) == 0
        assert topo.levels_crossed(0, topo.root) == 0
        assert topo.levels_crossed(0, topo.cpus[1]) == 1
        assert topo.levels_crossed(0, node[1]) == 2
        assert topo.levels_crossed(0, topo.cpus[15]) == 2

    @pytest.mark.parametrize("cm", COST_GRID)
    def test_total_cost_is_sum_of_per_steal_costs(self, cm):
        """The property the ledger must satisfy: total cost paid ==
        lock*steals + level*levels_crossed + thread*threads_moved, and the
        trace's per-steal costs are consistent with the per-steal
        distances it records."""
        reset_ids()
        topo = novascale_16()
        pol = StealPolicy(topo, cost_model=cm)
        tracer = Tracer(pol.sched)
        sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                        contention=0.5)
        r = sim.run(thrash_stripes_workload(), cycles=4)
        s = pol.sched.stats
        assert s.steals > 0
        want = (cm.lock_penalty * s.steals
                + cm.level_penalty * s.steal_distance
                + cm.thread_penalty * s.stolen_threads)
        assert s.steal_cost == pytest.approx(want)
        assert r.extra["steal_cost"] == pytest.approx(want)
        for e in tracer.steals():
            # every recorded steal crossed >=1 level (victims are never on
            # the thief's own covering chain) and paid at least the price
            # of moving one thread that far
            assert e.distance is not None and e.distance >= 1
            assert e.cost >= cm.steal_cost(e.distance, 1) - 1e-9

    def test_cost_slows_the_simulation(self):
        """Steal-happy runs must actually *pay*: same workload, same
        policy, nonzero penalties => strictly more simulated time."""
        def timed(cm):
            reset_ids()
            topo = novascale_16()
            pol = StealPolicy(topo, cost_model=cm)
            sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                            contention=0.5)
            return sim.run(thrash_stripes_workload(), cycles=4).time
        assert timed(StealCostModel(lock_penalty=2.0, level_penalty=4.0)) \
            > timed(StealCostModel())

    def test_zero_cost_config_reproduces_pr1_golden_traces(self):
        """Bit-for-bit: an explicit all-zero cost model must not perturb
        any golden trace (exact ==, no approx)."""
        import test_golden as tg
        for case in tg.CASES:
            reset_ids()
            topo = novascale_16()
            pol = StealPolicy(topo, cost_model=StealCostModel())
            root, cycles = tg._workload(case, "steal")
            sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                            contention=0.5)
            r = sim.run(root, cycles=cycles)
            want = tg.GOLDEN[(case, "steal")]
            assert round(r.time, 6) == want["time"]
            assert r.migrations == want["migrations"]
            assert r.data_migrations == want["data_migrations"]
            assert r.extra["steals"] == want["steals"]
            assert round(r.lookup_steps, 6) == round(want["lookup_steps"], 6)
            assert r.extra["steal_cost"] == 0.0

    def test_distance_scales_cost(self):
        """A cross-node steal (2 levels) must cost more than a sibling-cpu
        steal (1 level) under a level penalty."""
        cm = StealCostModel(level_penalty=3.0)
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=cm)
        sched.queues.queue_of(topo.cpus[1]).push(thread(1.0))   # sibling cpu
        sched._steal_pass(0)
        near = sched.stats.last_steal_cost
        sched.queues.queue_of(topo.components("node")[3]).push(thread(1.0))
        sched._steal_pass(0)
        far = sched.stats.last_steal_cost
        assert near == pytest.approx(3.0)
        assert far == pytest.approx(6.0)
        assert sched.stats.steal_distance == 3
        assert sched.consume_cost() == pytest.approx(9.0)
        assert sched.consume_cost() == 0.0                      # drained


# ---------------------------------------------------------------------------
# proactive rebalancing (AdaptivePolicy + BubbleScheduler.rebalance)
# ---------------------------------------------------------------------------

class TestRebalance:
    def test_rebalance_conserves_tasks(self):
        """Gather + re-spread must neither lose nor duplicate work."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        root = thrash_stripes_workload()
        sched.wake_up_bubble(root)
        for cpu in range(4):                    # burst some structure first
            t = sched.next_thread(cpu)
            if t is not None:
                t.remaining = 0.0
        before = {id(t) for t in root.threads() if t.remaining > 0}
        moves = sched.rebalance(0)
        assert moves > 0
        on_queues = []
        for q in sched.queues.queues.values():
            for task in q.tasks:
                if task.is_bubble():
                    on_queues.extend(id(x) for x in task.threads()
                                     if x.remaining > 0)
                elif task.remaining > 0:
                    on_queues.append(id(task))
        assert sorted(on_queues) == sorted(before)
        assert len(on_queues) == len(set(on_queues))   # no duplicates

    def test_rebalance_splits_overwide_bubbles(self):
        """Hierarchical re-placement: a bubble wider than one target
        component is expanded so no single list is flooded."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        fat = bubble(*[thread(2.0) for _ in range(16)], name="fat")
        sched.queues.queue_of(topo.components("node")[0]).push(fat)
        sched.rebalance(0)
        node_counts = []
        for comp in topo.components("node"):
            q = sched.queues.queue_of(comp)
            node_counts.append(sum(1 for t in q.tasks))
        assert fat not in [t for q in sched.queues.queues.values()
                           for t in q.tasks]
        assert max(node_counts) <= 4            # dealt out, not dumped

    def test_rebalance_marks_cross_node_moves_for_next_touch(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        t = thread(5.0)
        t.last_cpu = 12                          # homed on node3
        sched.queues.queue_of(topo.components("node")[3]).push(t)
        # stack node3 with enough work that LPT sends `t` elsewhere
        heavy = thread(50.0)
        heavy.last_cpu = 12
        sched.queues.queue_of(topo.components("node")[3]).push(heavy)
        sched.rebalance(0)
        holder = [q.comp.index for q in
                  (sched.queues.queue_of(c) for c in
                   topo.components("node")) if t in q.tasks]
        # LPT is deterministic: heavy (dealt first) takes one node, t the
        # next — t cannot stay on node3 and must be flagged for next-touch
        assert holder and holder[0] != 3
        assert t.stolen
        assert sched.stats.rebalances == 1
        assert sched.stats.rebalance_moves == 2

    def test_rebalance_billed_via_cost_model(self):
        cm = StealCostModel(rebalance_base=2.0, rebalance_per_move=0.5)
        topo = novascale_16()
        sched = BubbleScheduler(topo, cost_model=cm)
        for i in range(4):
            sched.queues.global_queue().push(thread(1.0))
        moves = sched.rebalance(0)
        assert moves == 4
        assert sched.stats.rebalance_cost == pytest.approx(4.0)
        assert sched.consume_cost() == pytest.approx(4.0)

    def test_adaptive_zero_cost_never_rebalances(self):
        """Cost-benefit trigger: free stealing => adaptive degrades into
        plain StealPolicy, bit-for-bit."""
        r_steal, _ = _sim(StealPolicy, imbalanced_stripes_workload)
        r_adapt, pol = _sim(AdaptivePolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.rebalances == 0
        assert r_adapt.time == r_steal.time
        assert r_adapt.extra["steals"] == r_steal.extra["steals"]

    def test_adaptive_rebalances_and_beats_costed_steal_on_thrash(self):
        """The tentpole acceptance behaviour: where per-steal cost makes
        reactive stealing thrash, proactive re-spreading wins.  Uses the
        same THRASH_COST price list as the benchmark's thrash section, so
        this asserts the shipped scenario."""
        r_steal, ps = _sim(StealPolicy, thrash_stripes_workload,
                           cost_model=THRASH_COST)
        r_adapt, pa = _sim(AdaptivePolicy, thrash_stripes_workload,
                           cost_model=THRASH_COST)
        assert pa.sched.stats.rebalances > 0
        assert pa.sched.stats.steal_cost + pa.sched.stats.rebalance_cost \
            < ps.sched.stats.steal_cost
        assert r_adapt.time < r_steal.time

    def test_tracer_records_rebalance_events(self):
        topo = novascale_16()
        pol = AdaptivePolicy(topo, cost_model=THRASH_COST)
        tracer = Tracer(pol.sched)
        sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                        contention=0.5)
        sim.run(thrash_stripes_workload(), cycles=4)
        rebs = tracer.rebalances()
        assert len(rebs) == pol.sched.stats.rebalances > 0
        assert all(e.kind == "rebalance" and e.cost > 0 for e in rebs)
        assert tracer.steals_by_level()          # per-level histogram filled


# ---------------------------------------------------------------------------
# end-to-end: the ISSUE acceptance comparison
# ---------------------------------------------------------------------------

class TestAcceptance:
    def test_steal_beats_simple_on_imbalanced(self):
        r_simple, _ = _sim(SimplePolicy,
                           lambda: imbalanced_stripes_workload(flat=True),
                           disorder=4.0)
        r_steal, pol = _sim(StealPolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0
        assert r_steal.time < r_simple.time            # strictly less

    def test_steal_beats_firsttouch_stealing_on_imbalanced(self):
        r_bub, _ = _sim(BubblePolicy, imbalanced_stripes_workload)
        r_steal, _ = _sim(StealPolicy, imbalanced_stripes_workload)
        assert r_steal.time < r_bub.time

    def test_nosteal_strands_idle_nodes(self):
        r_off, _ = _sim(BubblePolicy, imbalanced_stripes_workload,
                        steal=False)
        r_on, _ = _sim(BubblePolicy, imbalanced_stripes_workload)
        assert r_on.time < r_off.time

    def test_steal_no_worse_than_bubbles_on_balanced(self):
        def balanced():
            return stripes_workload(n_threads=16, work=100.0, group=4)
        r_bub, _ = _sim(BubblePolicy, balanced)
        r_steal, _ = _sim(StealPolicy, balanced)
        assert r_steal.time <= r_bub.time
