"""Hierarchical work-stealing + next-touch migration engine tests.

Covers the §3.3.3 steal pass: conservation (no task lost or duplicated
across steal/regenerate cycles), the affinity invariant (loot comes from
the closest level that had any, whole bubbles preferred, and lands inside
the thief's covering chain), `SchedStats` counter correctness, the
identity-safe run-queue removal the steal path depends on, and the
simulator's next-touch data migration.
"""

import pytest

from repro.core import (BubblePolicy, BubbleScheduler, Level, QueueHierarchy,
                        SimplePolicy, Simulator, StealPolicy, Topology,
                        bubble, imbalanced_stripes_workload, novascale_16,
                        reset_ids, stripes_workload, thread)
from repro.core.runqueues import RunQueue
from repro.core.trace import Tracer


# ---------------------------------------------------------------------------
# run-queue removal: identity, not equality (regression)
# ---------------------------------------------------------------------------

class TestRunQueueIdentity:
    def _queue(self):
        topo = Topology([Level("root", 1), Level("cpu", 1)])
        return QueueHierarchy(topo).global_queue()

    def test_remove_twin_is_identity_safe(self):
        """Two structurally-identical threads: removing the second must not
        delete the first (the old equality-based removal pulled whichever
        twin sat closest to the head)."""
        q = self._queue()
        a = thread(1.0, name="twin")
        b = thread(1.0, name="twin")
        q.push(a)
        q.push(b)
        assert q.remove(b)
        assert len(q) == 1 and q.tasks[0] is a

    def test_pop_best_claims_exact_object_at_non_head(self):
        q = self._queue()
        lo = thread(1.0, name="lo", prio=0)
        hi1 = thread(1.0, name="hi", prio=5)
        hi2 = thread(1.0, name="hi", prio=5)
        for t in (lo, hi1, hi2):
            q.push(t)
        got = q.pop_best()
        assert got is hi1                       # FIFO among equals
        assert list(q.tasks) == [lo, hi2]
        assert q.tasks[1] is hi2                # hi2 untouched, not a copy

    def test_remove_missing_returns_false(self):
        q = self._queue()
        q.push(thread(1.0))
        assert not q.remove(thread(1.0))
        assert len(q) == 1

    def test_version_bumped_on_removal(self):
        q = self._queue()
        t = thread(1.0)
        q.push(t)
        v = q.version
        q.remove(t)
        assert q.version > v                    # pass-2 revalidation sees it


# ---------------------------------------------------------------------------
# the steal pass itself
# ---------------------------------------------------------------------------

class TestStealPass:
    def test_steals_whole_bubble_over_thread(self):
        """At one level, a closed bubble beats any lone thread — moving the
        coherent group keeps its internal affinity intact."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        node1 = topo.components("node")[1]
        fat = thread(50.0, name="fat")
        grp = bubble(thread(2.0), thread(2.0), name="grp")
        sched.queues.queue_of(node1).push(fat)
        sched.queues.queue_of(node1).push(grp)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is grp
        assert sched.stats.bubble_steals == 1
        assert sched.stats.thread_steals == 0

    def test_closest_level_wins_over_heavier_loot(self):
        """A small thread on a sibling cpu queue (same node) is preferred
        over a big bubble a node away: most-local victim first."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        near = thread(1.0, name="near")
        sched.queues.covering(3)[0].push(near)        # cpu3: node0 sibling
        far = bubble(*[thread(9.0) for _ in range(4)], name="far")
        sched.queues.queue_of(topo.components("node")[2]).push(far)
        got = sched._steal_pass(0)
        assert got is not None and got[1] is near

    def test_stolen_threads_are_marked_for_next_touch(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(thread(2.0), thread(2.0), name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        _, loot = sched._steal_pass(0)
        assert loot is grp
        assert all(t.stolen for t in grp.threads())

    def test_placement_lands_in_thief_covering_chain(self):
        """The affinity invariant: loot is re-pushed onto the nearest list
        of the thief wide enough to hold it."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(*[thread(2.0) for _ in range(4)], name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        victim, loot = sched._steal_pass(0)
        sched._place_near(loot, 0)
        chain = sched.queues.covering(0)
        holder = [q for q in chain if loot in q.tasks]
        assert holder, "stolen bubble must sit on a queue covering the thief"
        # width 4 fits exactly at node level — not dumped on the global list
        assert holder[0].level == "node"
        assert victim.comp.name == "node3"

    def test_steal_respects_disable_flag(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo, steal=False)
        grp = bubble(thread(2.0), name="grp")
        sched.queues.queue_of(topo.components("node")[3]).push(grp)
        assert sched.next_thread(0) is None
        assert sched.stats.steals == 0
        # the loot is untouched on its home queue
        assert grp in sched.queues.queue_of(topo.components("node")[3]).tasks

    def test_steal_counters_add_up(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        sched.queues.queue_of(topo.components("node")[1]).push(
            bubble(thread(2.0), name="g1"))
        sched.queues.queue_of(topo.components("node")[2]).push(
            thread(3.0, name="solo"))
        assert sched._steal_pass(0) is not None
        assert sched._steal_pass(0) is not None
        assert sched._steal_pass(0) is None            # nothing left
        s = sched.stats
        assert s.steals == 2
        assert s.steals == s.bubble_steals + s.thread_steals
        assert s.steal_attempts == 3
        assert s.stolen_work == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# conservation + integration through next_thread
# ---------------------------------------------------------------------------

def _drive_to_exhaustion(sched, topo):
    got = []
    idle_rounds = 0
    while idle_rounds < 2:
        progressed = False
        for cpu in range(topo.n_cpus):
            t = sched.next_thread(cpu)
            if t is not None:
                got.append(t)
                t.remaining = 0.0
                progressed = True
        idle_rounds = 0 if progressed else idle_rounds + 1
    return got


class TestConservation:
    def test_unbalanced_tree_schedules_every_thread_once(self):
        """All work sits under one node; the other three must steal.  No
        thread may be lost or scheduled twice."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        root = bubble(*[bubble(*[thread(1.0) for _ in range(4)],
                               name=f"g{i}", burst_level="node")
                        for i in range(8)], name="app")
        node0 = topo.components("node")[0]
        sched.wake_up_bubble(root, at=sched.queues.queue_of(node0))
        got = _drive_to_exhaustion(sched, topo)
        want = list(root.threads())
        assert sorted(t.tid for t in got) == sorted(t.tid for t in want)
        assert sched.stats.steals > 0
        for q in sched.queues.queues.values():
            for task in q.tasks:
                assert task.is_bubble()       # only burst husks may remain

    def test_steal_then_regenerate_conserves(self):
        """Steal a bubble, burst it remotely, regenerate it — nothing is
        lost or duplicated across the cycle."""
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        grp = bubble(*[thread(5.0) for _ in range(4)], name="grp")
        node3 = topo.components("node")[3]
        sched.wake_up_bubble(grp, at=sched.queues.queue_of(node3))
        t = sched.next_thread(0)               # cpu0 steals + bursts locally
        assert t is not None and sched.stats.steals == 1
        sched.regenerate(grp, running={0: t})
        sched.thread_returned(t)
        # every thread is back inside the (single) closed bubble on a queue
        assert sched.queues.total_tasks() == 1
        assert not grp.burst
        remaining = {id(x) for x in grp.threads()}
        assert len(remaining) == 4


class TestCountersAndTrace:
    def test_trace_records_steal_victim_level(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        tracer = Tracer(sched)
        grp = bubble(*[thread(2.0) for _ in range(4)], name="grp")
        sched.wake_up_bubble(grp, at=sched.queues.queue_of(
            topo.components("node")[2]))
        t = sched.next_thread(0)
        assert t is not None
        steals = tracer.steals()
        assert len(steals) == sched.stats.steals == 1
        assert steals[0].task == "grp"
        assert steals[0].level == "node"

    def test_migration_counter_counts_cpu_changes(self):
        topo = novascale_16()
        sched = BubbleScheduler(topo)
        t = thread(2.0, name="mover")
        sched.submit_thread(t)
        assert sched.next_thread(3) is t
        sched.queues.global_queue().push(t)
        assert sched.next_thread(9) is t
        assert sched.stats.migrations == 1


# ---------------------------------------------------------------------------
# next-touch data migration (simulator side)
# ---------------------------------------------------------------------------

def _sim(policy_cls, root_fn, mem=0.25, cycles=8, **kw):
    reset_ids()
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    root = root_fn()
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=cycles), pol


class TestNextTouch:
    def test_steal_policy_selects_next_touch(self):
        topo = novascale_16()
        sim = Simulator(topo, StealPolicy(topo))
        assert sim.data_policy == "next_touch"
        sim2 = Simulator(topo, StealPolicy(topo), data_policy="first_touch")
        assert sim2.data_policy == "first_touch"       # explicit arg wins
        assert Simulator(topo, BubblePolicy(topo)).data_policy == "first_touch"

    def test_stolen_work_rehomes_on_next_touch(self):
        r, pol = _sim(StealPolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0
        assert r.data_migrations > 0
        assert r.extra["data_policy"] == "next_touch"

    def test_first_touch_never_migrates_data(self):
        r, pol = _sim(BubblePolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0              # stealing happened...
        assert r.data_migrations == 0                  # ...but data stayed put

    def test_rehome_updates_home_map(self):
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol)
        t = thread(4.0, data="page")
        sim.homes["page"] = 12                         # homed on node3
        t.stolen = True
        assert sim._speed(0, t) == 1.0                 # migrating touch
        assert sim.homes["page"] == 0                  # re-homed under thief
        assert sim.data_migrations == 1
        assert not t.stolen                            # flag is one-shot
        assert sim._speed(0, t) == 1.0                 # now local for real

    def test_result_counters_are_per_run_deltas(self):
        """A reused Simulator must report each run's own steal/migration
        counts, not lifetime cumulatives (regression)."""
        reset_ids()
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol, jitter=0.1, mem_fraction=0.25,
                        contention=0.5)
        r1 = sim.run(imbalanced_stripes_workload(), cycles=3)
        r2 = sim.run(imbalanced_stripes_workload(), cycles=3)
        assert r1.extra["steals"] > 0
        assert r1.extra["steals"] + r2.extra["steals"] == \
            pol.sched.stats.steals
        assert r1.data_migrations + r2.data_migrations == sim.data_migrations

    def test_migration_cost_charged_on_moving_touch(self):
        topo = novascale_16()
        pol = StealPolicy(topo)
        sim = Simulator(topo, pol, migration_cost=1.0)
        t = thread(4.0, data="page")
        sim.homes["page"] = 12
        t.stolen = True
        assert sim._speed(0, t) == pytest.approx(0.5)  # pays the move once
        assert sim._speed(0, t) == 1.0


# ---------------------------------------------------------------------------
# end-to-end: the ISSUE acceptance comparison
# ---------------------------------------------------------------------------

class TestAcceptance:
    def test_steal_beats_simple_on_imbalanced(self):
        r_simple, _ = _sim(SimplePolicy,
                           lambda: imbalanced_stripes_workload(flat=True),
                           disorder=4.0)
        r_steal, pol = _sim(StealPolicy, imbalanced_stripes_workload)
        assert pol.sched.stats.steals > 0
        assert r_steal.time < r_simple.time            # strictly less

    def test_steal_beats_firsttouch_stealing_on_imbalanced(self):
        r_bub, _ = _sim(BubblePolicy, imbalanced_stripes_workload)
        r_steal, _ = _sim(StealPolicy, imbalanced_stripes_workload)
        assert r_steal.time < r_bub.time

    def test_nosteal_strands_idle_nodes(self):
        r_off, _ = _sim(BubblePolicy, imbalanced_stripes_workload,
                        steal=False)
        r_on, _ = _sim(BubblePolicy, imbalanced_stripes_workload)
        assert r_on.time < r_off.time

    def test_steal_no_worse_than_bubbles_on_balanced(self):
        def balanced():
            return stripes_workload(n_threads=16, work=100.0, group=4)
        r_bub, _ = _sim(BubblePolicy, balanced)
        r_steal, _ = _sim(StealPolicy, balanced)
        assert r_steal.time <= r_bub.time
