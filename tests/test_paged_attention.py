"""Paged-KV decode: kernel oracle checks, backend stream parity, and the
zero-copy migration property.

Four layers, cheapest first:

* **kernel** — ``kernels.paged_attention.paged_attn`` (interpret mode on
  CPU) against both oracles: the paged gather oracle
  (``ref.paged_sdpa_ref``) across GQA ratios / sliding window / ragged
  per-slot page counts, and the *dense* ``ref.sdpa_ref`` on each slot's
  contiguous history — proving the block-table indirection is invisible.
* **backend parity** — ``PagedJaxModelBackend`` vs ``JaxModelBackend``
  driven through prefill → splice → decode on reduced zoo configs
  (transformer and rwkv): identical token streams, including through the
  lazy page-allocation boundary (the first decode that crosses into an
  unmapped page) and with the Pallas kernel swapped in.
* **engine property** — a single-host ``ServingEngine`` trace with gang
  regeneration (park → re-splice mid-flight): the paged engine's streams
  equal the dense engine's token for token while its KV pool is never
  copied (``pool_copies == 0``) — every migration was a block-table edit
  (``table_splices > 0``).
* **batch-axis spec** — ``api.batch_axis_spec`` unit tests, including the
  regression the spec exists for: a genuine 1-D ``(B,)`` per-slot leaf,
  which the old ``ndim >= 2`` heuristic silently skipped on splice
  (resuming a request with another request's state had any model carried
  one).
* **agentic prefix reuse** — a tool-calling session that sleeps
  mid-decode and wakes with its prefix KV pages still resident resumes
  as a block-table re-point (``table_splices > 0``, ``pool_copies == 0``,
  no re-prefill), and its stream is bit-identical to a cold wake whose KV
  was stale-evicted and re-prefilled from the token history.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels import paged_attention, ref
from repro.models import api
from repro.serving import (JaxModelBackend, PagedJaxModelBackend,
                           ServingEngine)

PS = 8            # page size
PPS = 4           # pages per slot


def _paged_case(rng, B, K, g, hd, lengths):
    """Random pool + ragged block tables: slot b owns ceil(len/PS) pages
    at shuffled pool indices, unused table entries 0 (the trash page)."""
    q = jnp.asarray(rng.standard_normal((B, K, g, hd)), jnp.float32)
    P = 1 + B * PPS
    k_pool = jnp.asarray(rng.standard_normal((P, PS, K, hd)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((P, PS, K, hd)), jnp.float32)
    tables = np.zeros((B, PPS), np.int32)
    perm = rng.permutation(np.arange(1, P))
    used = 0
    for b, ln in enumerate(lengths):
        n = -(-ln // PS) if ln else 0
        tables[b, :n] = perm[used:used + n]
        used += n
    return q, k_pool, v_pool, jnp.asarray(tables), \
        jnp.asarray(np.asarray(lengths, np.int32))


class TestPagedKernel:
    @pytest.mark.parametrize("K,g", [(4, 1), (2, 2), (1, 8)])
    @pytest.mark.parametrize("window", [None, 6])
    def test_matches_paged_oracle(self, K, g, window):
        rng = np.random.default_rng(0)
        lengths = [5, 8, 17, 1]                    # ragged page counts
        q, kp, vp, tbl, ln = _paged_case(rng, 4, K, g, 16, lengths)
        got = paged_attention.paged_attn(q, kp, vp, tbl, ln,
                                         window=window, scale=0.25,
                                         interpret=True)
        want = ref.paged_sdpa_ref(q, kp, vp, tbl, ln,
                                  window=window, scale=0.25)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_matches_dense_oracle_per_slot(self):
        """Gather each slot's pages back into a contiguous (1, L, H, hd)
        history and run plain causal SDPA: the paged kernel's answer is
        the dense answer's last row — the indirection is invisible."""
        K, g, hd = 2, 2, 16
        rng = np.random.default_rng(1)
        lengths = [5, 8, 17, 32]
        q, kp, vp, tbl, ln = _paged_case(rng, 4, K, g, hd, lengths)
        got = paged_attention.paged_attn(q, kp, vp, tbl, ln,
                                         scale=hd ** -0.5, interpret=True)
        for b, L in enumerate(lengths):
            hist_k = np.asarray(kp[tbl[b]]).reshape(-1, K, hd)[:L]
            hist_v = np.asarray(vp[tbl[b]]).reshape(-1, K, hd)[:L]
            # GQA: expand K kv heads to H = K*g query heads
            qh = np.asarray(q[b]).reshape(1, 1, K * g, hd)
            kh = np.repeat(hist_k, g, axis=1)[None]
            vh = np.repeat(hist_v, g, axis=1)[None]
            # query is the LAST position of the history: pad q to L rows
            qfull = np.concatenate(
                [np.zeros((1, L - 1, K * g, hd), np.float32), qh], axis=1)
            want = ref.sdpa_ref(jnp.asarray(qfull), jnp.asarray(kh),
                                jnp.asarray(vh), scale=hd ** -0.5)[0, -1]
            np.testing.assert_allclose(
                np.asarray(got[b]).reshape(K * g, hd), want,
                atol=2e-5, rtol=2e-5)

    def test_free_slot_rows_finite(self):
        """lengths == 0 rows (freed slots decoding into the trash page)
        must produce finite garbage, exactly like the dense path."""
        rng = np.random.default_rng(2)
        q, kp, vp, tbl, ln = _paged_case(rng, 3, 2, 2, 16, [7, 0, 0])
        got = paged_attention.paged_attn(q, kp, vp, tbl, ln,
                                         interpret=True)
        assert np.isfinite(np.asarray(got)).all()


def _bstreams(cfg, params, backend_cls, steps=6, **kw):
    """prefill → splice → decode loop straight through a backend (no
    engine): returns the per-slot greedy streams."""
    backend = backend_cls(cfg, params, 32, **kw)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 97, 6) for _ in range(3)]
    states, tokens = backend.init(3)
    out = [[] for _ in range(3)]
    for i, (tok, h) in enumerate(backend.prefill_wave(prompts)):
        tokens[i, 0] = tok
        out[i].append(tok)
        states = backend.splice(states, [(i, h)])
    for _ in range(steps):
        nxt, states = backend.decode(tokens, states)
        for i in range(3):
            out[i].append(int(nxt[i]))
            tokens[i, 0] = nxt[i]
    return [tuple(s) for s in out], backend


class TestBackendParity:
    @pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-3b"])
    def test_paged_streams_equal_dense(self, arch):
        """6 decode steps crosses a page boundary (prompt 6 + 6 > 8 = one
        page), so the lazy-allocation path is on the line too."""
        cfg = get_config(arch).reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        dense, _ = _bstreams(cfg, params, JaxModelBackend)
        paged, pb = _bstreams(cfg, params, PagedJaxModelBackend,
                              page_size=PS)
        assert dense == paged
        assert pb.stats["pool_copies"] == 0

    def test_kernel_path_streams_equal_dense(self):
        """The Pallas kernel (interpret mode) behind the paged backend:
        same greedy stream as the dense backend."""
        cfg = get_config("yi-6b").reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        dense, _ = _bstreams(cfg, params, JaxModelBackend, steps=3)
        paged, _ = _bstreams(cfg, params, PagedJaxModelBackend, steps=3,
                             page_size=PS, use_kernel=True)
        assert dense == paged


def _engine_run(cfg, params, backend):
    eng = ServingEngine(cfg, params, n_slots=8, cache_len=32,
                        backend=backend)
    rng = np.random.default_rng(0)
    gangs = ["g0", "g1"]
    n = 12
    for i in range(n):
        eng.submit(rng.integers(1, 97, 6), int(rng.integers(2, 8)),
                   gang=gangs[i % 2] if i < 8 else None)
    steps = 0
    while not eng._drained() and steps < 2000:
        eng.step()
        steps += 1
        if steps % 3 == 0:
            eng.regenerate_gang(gangs[(steps // 3) % 2])
    assert len(eng.completed) == n
    return eng, {r.rid: tuple(r.out_tokens) for r in eng.completed}


class TestEngineZeroCopy:
    def test_park_splice_is_metadata_only(self):
        """Single-host trace with rolling gang regeneration: every parked
        request resumes mid-flight.  On the paged backend those resumes
        are block-table edits — the KV pool is never copied — and the
        streams still match the dense backend token for token."""
        cfg = get_config("yi-6b").reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        _, dense = _engine_run(cfg, params,
                               JaxModelBackend(cfg, params, 32))
        pb = PagedJaxModelBackend(cfg, params, 32, page_size=PS)
        ep, paged = _engine_run(cfg, params, pb)
        assert dense == paged
        assert ep.stats.kv_parks > 0              # the path really ran
        assert pb.stats["table_splices"] > 0      # resumes were metadata
        assert pb.stats["pool_copies"] == 0       # ... and ONLY metadata
        assert pb.stats["pool_page_writes"] > 0   # prefills did page in


class TestAgenticPrefixReuse:
    @staticmethod
    def _session_run(cfg, params, **kw):
        pb = PagedJaxModelBackend(cfg, params, 32, page_size=PS)
        eng = ServingEngine(cfg, params, n_slots=4, cache_len=32,
                            backend=pb, **kw)
        rng = np.random.default_rng(3)
        # prompt 6 + turn 1's 4 tokens cross the PS=8 page boundary, so
        # the parked handle spans two pages when the session sleeps
        eng.submit(rng.integers(1, 97, 6), 10, tool_calls=((4, 5),))
        eng.run(max_steps=500)
        assert len(eng.completed) == 1
        return eng, pb, tuple(eng.completed[0].out_tokens)

    def test_warm_wake_is_table_repoint_cold_wake_is_bit_identical(self):
        """A woken session whose prefix KV pages are still resident skips
        prefill entirely: the resume is a block-table re-point with zero
        pool copies.  Forcing the same session through a stale eviction
        (``session_ttl`` shorter than the think gap) rebuilds its KV from
        the token history — and must produce the bit-identical stream."""
        cfg = get_config("yi-6b").reduced(vocab=97)
        params = api.init(cfg, jax.random.PRNGKey(0))
        warm_eng, warm_pb, warm = self._session_run(cfg, params)
        c = warm_eng.counters()
        assert c["sleeps"] == c["wakes"] == 1
        assert c["wake_reprefills"] == 0          # prefix pages were resident
        assert warm_eng.stats.prefills == 1       # the one fresh prefill
        assert warm_pb.stats["table_splices"] > 0  # wake was metadata
        assert warm_pb.stats["pool_copies"] == 0   # ... and ONLY metadata
        cold_eng, cold_pb, cold = self._session_run(cfg, params,
                                                    session_ttl=2)
        cc = cold_eng.counters()
        assert cc["stale_evictions"] == 1          # KV dropped past the TTL
        assert cc["wake_reprefills"] == 1          # wake rebuilt it
        assert cold == warm                        # bit-identical stream


class TestBatchAxisSpec:
    @staticmethod
    def _init(n):
        return {"cache": jnp.zeros((2, n, 8)),      # reps-stacked, axis 1
                "flag": jnp.zeros((n,)),            # 1-D per-slot leaf
                "pool": jnp.zeros((7, 4)),          # batch-free
                "scalar": jnp.zeros(())}

    def test_axes_inferred(self):
        axes = api.batch_axis_spec(self._init)
        assert axes == {"cache": 1, "flag": 0, "pool": -1, "scalar": -1}

    def test_multi_axis_leaf_rejected(self):
        with pytest.raises(ValueError, match="varies on 2 axes"):
            api.batch_axis_spec(lambda n: {"bad": jnp.zeros((n, n))})

    def test_1d_leaf_spliced_not_skipped(self):
        """THE regression the spec fixes: the old ``b.ndim >= 2`` guard
        returned 1-D leaves untouched, so a ``(B,)`` per-slot leaf kept
        the evicted request's value after a splice.  The spec-driven
        write (the exact ``JaxModelBackend.splice`` traversal) updates
        it."""
        axes = api.batch_axis_spec(self._init)
        states = {"cache": jnp.zeros((2, 4, 8)),
                  "flag": jnp.arange(4.0),
                  "pool": jnp.zeros((7, 4)), "scalar": jnp.zeros(())}
        one = {"cache": jnp.ones((2, 1, 8)), "flag": jnp.full((1,), 9.0),
               "pool": jnp.zeros((7, 4)), "scalar": jnp.zeros(())}
        slots = jnp.asarray([2])

        def write(ax, b, new):
            if ax < 0:
                return b
            idx = (slice(None),) * ax + (slots,)
            return b.at[idx].set(jnp.concatenate([new], axis=ax))

        out = jax.tree.map(write, axes, states, one)
        assert out["flag"][2] == 9.0              # heuristic left this 2.0
        assert out["cache"][:, 2].sum() == 16.0
        assert (out["pool"] == states["pool"]).all()

    def test_model_zoo_states_all_resolve(self):
        """Every zoo decode state must yield a spec (no multi-axis leaf,
        attention/recurrent alike) — the dense backend builds this in its
        constructor, so a failure here is a backend constructor failure."""
        from repro.models import lm
        for arch in ("yi-6b", "rwkv6-3b", "recurrentgemma-9b"):
            cfg = get_config(arch).reduced(vocab=97)
            axes = api.batch_axis_spec(
                lambda n, c=cfg: lm.init_state(c, n, 32))
            leaves = jax.tree.leaves(axes)
            assert leaves and all(a in (-1, 0, 1) for a in leaves), \
                (arch, leaves)
