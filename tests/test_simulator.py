"""Paper-reproduction assertions: Table 2, Fig 5, scheduler behaviour."""

import pytest

from repro.core import (BoundPolicy, BubblePolicy, PerCpuPolicy, SimplePolicy,
                        Simulator, bi_xeon_ht, fibonacci_workload,
                        novascale_16, stripes_workload)


def _table2(policy_cls, group=None, mem=0.25, **kw):
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    root = stripes_workload(16, work=100.0, group=group)
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=8)


class TestTable2:
    """Conduction/advection on the 16-cpu 4-node ccNUMA (paper §5.2).

    Paper values: simple 10.58, bound 15.82, bubbles 15.80 (conduction);
    simple 9.11, bound 12.40, bubbles 12.40 (advection)."""

    def test_simple_matches_paper_conduction(self):
        r = _table2(SimplePolicy, disorder=4.0)
        assert 9.0 < r.speedup < 12.5, r.speedup

    def test_bound_matches_paper(self):
        r = _table2(BoundPolicy)
        assert r.speedup > 15.0

    def test_bubbles_match_bound(self):
        rb = _table2(BoundPolicy)
        ru = _table2(BubblePolicy, group=4)
        # the paper's headline: portable bubbles ≈ non-portable bound
        assert abs(rb.speedup - ru.speedup) / rb.speedup < 0.05

    def test_bubbles_beat_simple_by_30pct(self):
        rs = _table2(SimplePolicy, disorder=4.0)
        ru = _table2(BubblePolicy, group=4)
        assert ru.speedup / rs.speedup > 1.3     # paper: ~1.5x

    def test_advection_ordering(self):
        rs = _table2(SimplePolicy, mem=0.4, disorder=4.0)
        ru = _table2(BubblePolicy, group=4, mem=0.4)
        assert ru.speedup > rs.speedup * 1.25

    def test_percpu_between(self):
        r = _table2(PerCpuPolicy)
        assert r.speedup > 14.0     # AFS-style keeps affinity here


def _fib_gain(n, topo_fn, gs, mem=0.6):
    ts = {}
    for with_b in (False, True):
        topo = topo_fn()
        pol = BubblePolicy(topo) if with_b else SimplePolicy(topo, disorder=4.0)
        root = fibonacci_workload(n, with_bubbles=with_b, group_size=gs)
        r = Simulator(topo, pol, mem_fraction=mem, contention=0.5).run(root)
        ts[with_b] = r.time
    return (ts[False] - ts[True]) / ts[False] * 100


class TestFig5:
    """Fibonacci: gain from expressing the recursion as bubbles."""

    @pytest.mark.parametrize("n,lo", [(16, 25), (32, 25), (128, 20), (512, 20)])
    def test_numa_gain(self, n, lo):
        # paper: 40% at 32 threads, up to 80% at 512
        assert _fib_gain(n, novascale_16, gs=4) > lo

    @pytest.mark.parametrize("n,lo", [(8, 15), (16, 10)])
    def test_xeon_gain(self, n, lo):
        # paper: 30-40% stabilised
        assert _fib_gain(n, bi_xeon_ht, gs=2) > lo


class TestSpeedModel:
    def test_numa_factor_applied(self):
        topo = novascale_16()
        sim = Simulator(topo, BoundPolicy(topo), mem_fraction=1.0)
        sim.homes["d"] = 0
        from repro.core.bubble import thread
        t = thread(1.0, data="d")
        assert sim._speed(0, t) == 1.0
        assert sim._speed(1, t) == 1.0          # same node
        assert abs(sim._speed(4, t) - 1 / 3) < 1e-9   # remote node

    def test_mem_fraction_soften(self):
        topo = novascale_16()
        sim = Simulator(topo, BoundPolicy(topo), mem_fraction=0.25)
        sim.homes["d"] = 0
        from repro.core.bubble import thread
        t = thread(1.0, data="d")
        assert abs(sim._speed(4, t) - 1 / 1.5) < 1e-9

    def test_first_touch(self):
        topo = novascale_16()
        sim = Simulator(topo, BoundPolicy(topo))
        from repro.core.bubble import thread
        t = thread(1.0, data="x")
        assert sim._speed(5, t) == 1.0          # first touch homes at 5
        assert sim.homes["x"] == 5
