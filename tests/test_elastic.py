"""Elastic fleet: live host loss / join with checkpointed KV recovery.

Three layers are pinned here:

* **dynamic Topology** — components can leave and join the machine tree
  live; cpu ids are append-only, dead names never resolve again, crossing
  queries stay correct across the mutation, and a topology that is never
  mutated behaves exactly as before (the goldens separately pin
  byte-identical static behaviour);
* **QueueHierarchy.sync** — queues survive for live components, detached
  queues must be empty (tasks are re-homed *before* surgery);
* **ServingEngine.kill_host / join_host** — the tentpole: a mid-flight
  host loss orphans its residents, restores each from the checkpointed KV
  store or re-prefills (whichever the bill model quotes cheaper), and the
  surviving fleet re-deals; a join grows capacity live.  The stub
  backend's hash-of-history output makes stream equality a full-integrity
  check: every surviving request must finish with exactly the tokens an
  undisturbed run produces.
"""

import numpy as np
import pytest

from repro.checkpoint import KVStore
from repro.core import BubbleScheduler, bubble, thread
from repro.core.scheduler import StealCostModel
from repro.serving import (SERVE_COST, ServingEngine, StubModelBackend,
                           slots_topology)


def make_engine(**kw):
    kw.setdefault("n_slots", 16)
    kw.setdefault("hosts", 2)
    kw.setdefault("cost_model", SERVE_COST)
    return ServingEngine(None, None, backend=StubModelBackend(), **kw)


def submit(eng, n, prompt_len=20, new_tokens=24, seed=0, **kw):
    rng = np.random.default_rng(seed)
    rids = [eng.submit(rng.integers(1, 200, prompt_len), new_tokens,
                       prio=0, **kw) for _ in range(n)]
    return rids


def streams(eng):
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


# ---------------------------------------------------------------------------
# dynamic Topology
# ---------------------------------------------------------------------------

class TestDynamicTopology:
    def test_static_topology_is_inert(self):
        topo = slots_topology(16, 4, hosts=2)
        assert topo.version == 0
        assert topo.dead_cpus == set()
        assert topo.live_cpus() == list(range(16))

    def test_remove_detaches_subtree(self):
        topo = slots_topology(16, 4, hosts=2)
        doomed = {leaf.cpu for leaf in topo.component("host1").leaves()}
        removed = topo.remove_component("host1")
        assert topo.version == 1
        assert topo.dead_cpus == doomed
        assert topo.live_cpus() == sorted(set(range(16)) - doomed)
        assert topo.n_cpus == 16                 # ids never renumber
        assert removed[0].name == "host1"
        with pytest.raises(KeyError):
            topo.component("host1")              # stale handle fails loudly
        assert "host1" not in [h.name for h in topo.components("host")]
        assert "(8 dead)" in topo.describe()

    def test_remove_guards(self):
        topo = slots_topology(16, 4, hosts=2)
        with pytest.raises(AssertionError):
            topo.remove_component("batch0")      # the root
        topo.remove_component("host1")
        with pytest.raises(AssertionError):
            topo.remove_component("host0")       # the last host

    def test_dead_leaf_path_still_prices(self):
        """A migration away from a dead region must price as an outermost
        crossing, not crash: detached components keep parent pointers."""
        topo = slots_topology(16, 4, hosts=2)
        topo.remove_component("host1")
        dead = next(iter(topo.dead_cpus))
        assert topo.distance_factor(0, dead) == 4.0     # host boundary
        assert topo.levels_crossed(0, topo.cpus[dead]) > 0

    def test_join_appends_fresh_ids_and_names(self):
        topo = slots_topology(16, 4, hosts=2)
        topo.remove_component("host1")
        host = topo.add_component("host", (2, 4))
        assert host.name == "host2"              # dead name never reused
        assert [leaf.cpu for leaf in host.leaves()] == list(range(16, 24))
        assert topo.version == 2
        # crossing queries see the new boundary
        assert topo.crossing_between(host, topo.component("host0")) == "host"
        assert topo.levels_crossed(16, topo.component("page0")) == 3

    def test_ragged_join(self):
        topo = slots_topology(16, 4, hosts=2)
        host = topo.add_component("host", (3, [2, 2, 1]))
        sizes = [len(p.children) for p in host.children]
        assert sizes == [2, 2, 1]
        assert topo.n_cpus == 21

    def test_fanout_arity_checked(self):
        topo = slots_topology(16, 4, hosts=2)
        with pytest.raises(AssertionError):
            topo.add_component("host", (2, 4, 4))   # one entry too many


# ---------------------------------------------------------------------------
# QueueHierarchy.sync
# ---------------------------------------------------------------------------

class TestQueueSync:
    def test_live_queues_survive_dead_queues_drop(self):
        sched = BubbleScheduler(slots_topology(16, 4, hosts=2))
        keep = sched.queues.queue_of(sched.topo.component("host0"))
        b = bubble(thread(2.0))
        keep.push(b)
        sched.topo.remove_component("host1")
        sched.queues.sync()
        assert sched.queues.queue_of(sched.topo.component("host0")) is keep
        assert list(keep.tasks) == [b]           # object identity survives
        assert set(sched.queues._cover) == set(sched.topo.live_cpus())

    def test_detached_queue_must_be_empty(self):
        sched = BubbleScheduler(slots_topology(16, 4, hosts=2))
        doomed = sched.queues.queue_of(sched.topo.component("host1"))
        doomed.push(bubble(thread(2.0)))
        sched.topo.remove_component("host1")
        with pytest.raises(AssertionError):
            sched.queues.sync()                  # caller forgot to re-home

    def test_join_grows_fresh_queues(self):
        sched = BubbleScheduler(slots_topology(16, 4, hosts=2))
        host = sched.topo.add_component("host", (2, 4))
        sched.queues.sync()
        q = sched.queues.queue_of(host)
        assert len(q) == 0
        chain = sched.queues.covering(16)
        assert [r.comp.level.name for r in chain] == \
            ["slot", "page", "host", "batch"]


# ---------------------------------------------------------------------------
# ServingEngine.kill_host — the failure path
# ---------------------------------------------------------------------------

class TestKillHost:
    def run_with_kill(self, kill_at, tmp_path=None, cadence=4, restart=False,
                      n=24, prompt_len=20, seed=0, **kw):
        store = None if tmp_path is None else KVStore(tmp_path, cadence)
        eng = make_engine(kv_store=store, **kw)
        rids = submit(eng, n, prompt_len=prompt_len, seed=seed)
        for _ in range(kill_at):
            eng.step()
        info = eng.kill_host("host1", restart=restart)
        eng.run(max_steps=2000)
        return eng, rids, info

    def reference(self, n=24, prompt_len=20, seed=0, **kw):
        eng = make_engine(**kw)
        submit(eng, n, prompt_len=prompt_len, seed=seed)
        eng.run(max_steps=2000)
        return eng

    def test_zero_loss_and_stream_equality(self, tmp_path):
        """The hard gate: every request completes, and every stream is
        token-for-token what the undisturbed fleet produces."""
        ref = self.reference()
        eng, rids, info = self.run_with_kill(10, tmp_path)
        assert sorted(streams(eng)) == sorted(rids)      # zero request loss
        assert streams(eng) == streams(ref)              # exact streams
        assert info["orphaned"] > 0
        assert eng.stats.kv_restores + eng.stats.reprefills \
            == info["orphaned"]

    def test_restore_wins_with_long_prompts(self, tmp_path):
        """SERVE_COST host toll (3.125 steps) beats re-prefilling a 20-token
        history — orphans must come back from the snapshot store."""
        eng, _, info = self.run_with_kill(10, tmp_path)
        assert info["restored"] > 0 and info["reprefilled"] == 0
        assert eng.counters()["kv_restores"] == info["restored"]

    def test_reprefill_wins_with_short_prompts(self, tmp_path):
        """A 4-token prompt re-prefills for ~1.25 steps — cheaper than the
        host-boundary restore toll; the quote must pick re-prefill even
        though a snapshot exists."""
        eng, rids, info = self.run_with_kill(6, tmp_path, prompt_len=4)
        assert info["reprefilled"] > 0 and info["restored"] == 0
        assert sorted(streams(eng)) == sorted(rids)

    def test_no_store_reprefills(self):
        ref = self.reference()
        eng, rids, info = self.run_with_kill(10, tmp_path=None)
        assert info["restored"] == 0
        assert streams(eng) == streams(ref)

    def test_stale_snapshot_replays_exactly(self, tmp_path):
        """Kill between snapshots: the newest snapshot is several tokens
        stale, so restore = transfer + teacher-forced replay of the gap.
        Streams must still be exact."""
        ref = self.reference()
        eng, _, info = self.run_with_kill(11, tmp_path, cadence=8)
        assert info["restored"] > 0
        assert streams(eng) == streams(ref)

    def test_dead_slots_never_readmit(self, tmp_path):
        eng, _, _ = self.run_with_kill(10, tmp_path)
        dead = eng._dead_slots
        assert dead == set(range(8, 16))
        for r in eng.completed:
            pass                                  # engine drained fine
        assert all(eng.slot_req[s] is None for s in dead)

    def test_queued_work_folds_to_survivors(self, tmp_path):
        """Requests homed on the dead host's list that never started must
        fold one level up and still complete on survivors."""
        eng = make_engine(kv_store=KVStore(tmp_path, 4))
        rids = submit(eng, 8)
        rng = np.random.default_rng(9)
        # oversubscribe the doomed host: 12 requests homed on its list can
        # occupy at most its 8 slots, so some are still queued at the kill
        rids += [eng.submit(rng.integers(1, 200, 20), 8, prio=0,
                            home="host1") for _ in range(12)]
        eng.step()
        info = eng.kill_host("host1")
        assert info["queued_moved"] + info["requeued_pending"] > 0
        eng.run(max_steps=2000)
        assert sorted(streams(eng)) == sorted(rids)

    def test_restart_baseline_loses_more_work(self, tmp_path):
        """The drain-and-restart operator tears down every in-flight
        request fleet-wide and ignores snapshots; it must re-prefill all of
        them and take at least as many steps as the elastic path."""
        ref = self.reference()
        elastic, _, _ = self.run_with_kill(10, tmp_path)
        base, rids, info = self.run_with_kill(10, tmp_path, restart=True)
        assert info["restored"] == 0
        assert info["orphaned"] >= 16            # the whole fleet, not a host
        assert streams(base) == streams(ref)     # still zero loss...
        assert base.steps >= elastic.steps       # ...but strictly more work

    def test_kill_guards(self):
        eng = make_engine()
        with pytest.raises(KeyError):
            eng.kill_host("host7")
        with pytest.raises(AssertionError):
            eng.kill_host("page0")               # not a host
        eng.kill_host("host1")
        with pytest.raises(AssertionError):
            eng.kill_host("host0")               # the last host

    def test_kv_store_needs_peek(self, tmp_path):
        class NoPeek(StubModelBackend):
            peek = None
        with pytest.raises(AssertionError):
            ServingEngine(None, None, n_slots=16, hosts=2, backend=NoPeek(),
                          kv_store=KVStore(tmp_path))


# ---------------------------------------------------------------------------
# ServingEngine.join_host — the scale-out path
# ---------------------------------------------------------------------------

class TestJoinHost:
    def test_join_grows_and_streams_match(self):
        ref = make_engine()
        submit(ref, 32, seed=1)
        ref.run(max_steps=2000)
        eng = make_engine()
        rids = submit(eng, 32, seed=1)
        for _ in range(6):
            eng.step()
        name = eng.join_host()
        assert name == "host2"
        assert eng.n_slots == 24
        eng.run(max_steps=2000)
        assert streams(eng) == streams(ref)
        assert sorted(streams(eng)) == sorted(rids)
        assert eng.stats.host_decode_steps[-1] > 0      # new host worked
        assert eng.counters()["host_joins"] == 1

    def test_join_after_kill_replaces_capacity(self, tmp_path):
        ref = make_engine()
        submit(ref, 24)
        ref.run(max_steps=2000)
        eng = make_engine(kv_store=KVStore(tmp_path, 4))
        submit(eng, 24)
        for _ in range(10):
            eng.step()
        eng.kill_host("host1")
        name = eng.join_host()
        assert name == "host2"                   # dead name stays dead
        eng.run(max_steps=2000)
        assert streams(eng) == streams(ref)

    def test_slow_joiner_speed_credit(self):
        eng = make_engine()
        submit(eng, 32, seed=1)
        eng.step()
        eng.join_host(speed=0.5)
        eng.run(max_steps=2000)
        g = len(eng._exec_groups) - 1
        # a 0.5-speed host decodes at most every other engine step
        assert eng.stats.host_decode_steps[g] <= eng.steps // 2 + 1

    def test_unattractive_join_skips_redeal(self):
        """With nothing queued there is nothing to re-spread: the proactive
        quote must not buy a rebalance (no spurious stalls)."""
        eng = make_engine()
        eng.join_host()
        assert eng.sched.stats.rebalances == 0

    def test_join_name_mismatch_caught(self):
        eng = make_engine()
        with pytest.raises(AssertionError):
            eng.join_host("host9")
