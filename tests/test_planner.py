"""Static bubble-scheduling planner tests."""

import pytest

from repro.configs import all_configs, get_config
from repro.core.bubble import bubble
from repro.core.planner import Dim, MeshAxis, plan_bound, plan_bubbles, plan_simple
from repro.models import bubble_tree

AXES1 = [MeshAxis("data", 16), MeshAxis("model", 16)]
AXES2 = [MeshAxis("pod", 2), MeshAxis("data", 16), MeshAxis("model", 16)]


class TestPlanner:
    def test_batch_takes_outer_axes(self):
        tree = bubble(bubble(Dim(name="batch", width=256), name="d"),
                      bubble(Dim(name="d_ff", width=1024, min_level="model",
                                 weight=2.0), name="f"))
        p = plan_bubbles(tree, AXES2)
        assert p.assignment["batch"] == ("pod", "data")
        assert p.assignment["d_ff"] == ("model",)

    def test_min_level_sinks_below_expensive_axes(self):
        tree = bubble(bubble(Dim(name="w", width=512, min_level="model"),
                             name="g"))
        p = plan_bubbles(tree, AXES2)
        assert p.assignment["w"] == ("model",)

    def test_same_bubble_dims_compete(self):
        tree = bubble(bubble(
            Dim(name="experts", width=64, weight=4.0, min_level="model"),
            Dim(name="d_ff", width=1408, weight=2.0, min_level="model"),
            name="moe"))
        p = plan_bubbles(tree, AXES1)
        # experts (heavier) wins the model axis; d_ff must not share it
        assert p.assignment["experts"] == ("model",)
        assert p.assignment["d_ff"] == ()

    def test_sibling_bubbles_share_axis(self):
        tree = bubble(
            bubble(Dim(name="heads", width=32, min_level="model"), name="a"),
            bubble(Dim(name="d_ff", width=1024, min_level="model"), name="f"))
        p = plan_bubbles(tree, AXES1)
        assert p.assignment["heads"] == ("model",)
        assert p.assignment["d_ff"] == ("model",)

    def test_width_must_fill_axis(self):
        tree = bubble(bubble(Dim(name="experts", width=8, weight=4.0,
                                 min_level="model"),
                             Dim(name="d_ff", width=32768, weight=2.0,
                                 min_level="model"), name="moe"))
        p = plan_bubbles(tree, AXES1)
        # 8 experts cannot fill a 16-wide axis -> d_ff gets it (grok case)
        assert p.assignment["experts"] == ()
        assert p.assignment["d_ff"] == ("model",)


class TestArchTrees:
    @pytest.mark.parametrize("arch", list(all_configs()))
    def test_every_arch_plans(self, arch):
        cfg = get_config(arch)
        tree = bubble_tree(cfg, "train_4k")
        p = plan_bubbles(tree, AXES2)
        assert p.assignment["batch"] == ("pod", "data")
        # something must occupy the model axis
        on_model = [d for d, ax in p.assignment.items() if "model" in ax]
        assert on_model, p.pretty()

    def test_deepseek_experts_win_model_axis(self):
        cfg = get_config("deepseek-moe-16b")
        p = plan_bubbles(bubble_tree(cfg, "train_4k"), AXES1)
        assert p.assignment["experts"] == ("model",)

    def test_grok_ffn_wins_model_axis(self):
        cfg = get_config("grok-1-314b")
        p = plan_bubbles(bubble_tree(cfg, "train_4k"), AXES1)
        assert p.assignment["d_ff"] == ("model",)
        assert p.assignment["experts"] == ()

    def test_rwkv_heads_flat_sharded(self):
        cfg = get_config("rwkv6-3b")
        p = plan_bubbles(bubble_tree(cfg, "train_4k"), AXES1)
        assert p.assignment["heads_flat"] == ("model",)


class TestBaselinePlans:
    def test_simple_plan_pure_dp(self):
        p = plan_simple("batch", AXES2)
        assert p.assignment["batch"] == ("pod", "data", "model")

    def test_bound_plan_passthrough(self):
        p = plan_bound({"batch": ("data",), "heads": ("model",)})
        assert p.axes_of("heads") == ("model",)
        assert p.axes_of("nonexistent") is None
