import jax

# Smoke tests and benches see the real (single) CPU device; only
# launch/dryrun.py sets XLA_FLAGS for 512 placeholder devices.
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # tier-1 runs `-m "not slow"` (Makefile); slow tests get their own
    # non-required CI lane so a 7-minute compile never gates a PR
    config.addinivalue_line(
        "markers", "slow: excluded from the tier-1 gate; run in the "
        "dedicated slow CI lane")
