import jax

# Smoke tests and benches see the real (single) CPU device; only
# launch/dryrun.py sets XLA_FLAGS for 512 placeholder devices.
jax.config.update("jax_enable_x64", False)
