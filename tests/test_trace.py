"""Scheduler trace tool tests (paper §6 'analysis tools based on tracing')."""

from repro.core import (BubblePolicy, Simulator, balanced_tree, novascale_16,
                        stripes_workload)
from repro.core.scheduler import BubbleScheduler
from repro.core.trace import Tracer


def test_trace_records_schedules_and_bursts():
    topo = novascale_16()
    sched = BubbleScheduler(topo)
    tracer = Tracer(sched)
    root = balanced_tree([4, 4], work=5.0)
    sched.wake_up_bubble(root)
    for cpu in range(16):
        t = sched.next_thread(cpu)
        if t is not None:
            t.remaining = 0.0
    s = tracer.summary()
    assert s.get("schedule", 0) == 16
    assert s.get("burst", 0) >= 4
    assert tracer.timeline()


def test_locality_report_on_bubble_schedule():
    """The bubbles policy must keep ≥90% of schedules data-local after the
    first (first-touch) cycle — the check the paper's tool is for."""
    topo = novascale_16()
    pol = BubblePolicy(topo)
    tracer = Tracer(pol.sched)
    root = stripes_workload(16, work=50.0, group=4)
    sim = Simulator(topo, pol, mem_fraction=0.25, contention=0.5)
    sim.run(root, cycles=4)
    rep = tracer.locality_report(topo, sim.homes, list(root.threads()))
    assert rep["total"] > 0
    assert rep["fraction"] >= 0.9, rep


def test_level_histogram_prefers_local_levels():
    topo = novascale_16()
    pol = BubblePolicy(topo)
    tracer = Tracer(pol.sched)
    root = stripes_workload(16, work=50.0, group=4)
    Simulator(topo, pol, mem_fraction=0.25).run(root, cycles=2)
    hist = tracer.level_histogram()
    # threads are released on node lists by bursting bubbles
    assert hist.get("node", 0) + hist.get("cpu", 0) > hist.get("machine", 0)
