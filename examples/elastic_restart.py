"""Fault tolerance demo: lose a pod mid-training, restart elastically.

1. Train a reduced model on a simulated 2-pod mesh (2x2x2 host devices).
2. "Lose" pod 1: rebuild the mesh from survivors, re-run the bubble planner
   against the smaller axis hierarchy, restore the latest checkpoint with
   the new shardings, and keep training — loss continues from where it was.

This is the paper's bubble regeneration at fleet scale: the application
tree is unchanged; only the machine side changed, so the scheduler
re-derives the distribution.

Run:  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
          python examples/elastic_restart.py
"""

from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.core.planner import MeshAxis, plan_bubbles
from repro.data import DataConfig, ShardedTokenStream
from repro.distributed import sharding as shard_mod
from repro.distributed.fault_tolerance import FleetSpec, rebuild_mesh, replan
from repro.launch.mesh import mesh_axes
from repro.models import api
from repro.optim import adamw

CKPT = "/tmp/repro_elastic"


def make_step(cfg, acfg):
    loss_fn = api.make_loss_fn(cfg)

    def step(params, opt, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        p, o = adamw.apply(g, opt, acfg, param_dtype=jnp.float32)
        return loss, p, o

    return jax.jit(step, donate_argnums=(0, 1))


def shard_params(cfg, plan, mesh, params):
    sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                      shard_mod.param_specs(cfg, plan, mesh))
    return jax.tree.map(jax.device_put, params, sh), sh


def main():
    cfg = get_config("yi-6b").reduced(n_layers=2)
    acfg = adamw.AdamWConfig(lr=1e-3, warmup=1)
    data = ShardedTokenStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                         global_batch=4))
    tree = api.bubble_tree(cfg, "train_4k")
    it = data.shard(0, 0)
    step_fn = make_step(cfg, acfg)

    # ---- phase 1: 2 pods ---------------------------------------------------
    spec = FleetSpec(pods=2, data=2, model=2)
    mesh = rebuild_mesh(spec)
    plan = replan(tree, mesh)
    print(f"phase 1 mesh: {dict(mesh_axes(mesh))}")
    params = api.init(cfg, jax.random.PRNGKey(0))
    with mesh:
        params, _ = shard_params(cfg, plan, mesh, params)
        opt = adamw.init(params)
        losses = []
        for s in range(4):
            loss, params, opt = step_fn(params, opt, next(it))
            losses.append(float(loss))
            print(f"  step {s}: loss {loss:.4f}")
        ckpt.save(CKPT, 4, params, extra={"mesh": dict(mesh_axes(mesh))})

    # ---- pod 1 dies ----------------------------------------------------------
    print("\n*** pod 1 lost — elastic restart on survivors ***\n")
    spec = FleetSpec(pods=2, data=2, model=2, dead_pods=frozenset({1}))
    mesh2 = rebuild_mesh(spec)
    plan2 = replan(tree, mesh2)
    print(f"phase 2 mesh: {dict(mesh_axes(mesh2))}")

    with mesh2:
        like = jax.tree.map(np.asarray, params)
        sh2 = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh2, s),
                           shard_mod.param_specs(cfg, plan2, mesh2))
        restored, man = ckpt.restore(CKPT, 4, like, shardings=sh2)
        print(f"restored step {man['step']} "
              f"(written on mesh {man['extra']['mesh']})")
        opt2 = adamw.init(restored)
        params2 = restored
        for s in range(4, 7):
            loss, params2, opt2 = step_fn(params2, opt2, next(it))
            print(f"  step {s}: loss {loss:.4f}")
            assert np.isfinite(float(loss))
    print("\nelastic restart OK: training continued on the shrunken fleet")


if __name__ == "__main__":
    main()
