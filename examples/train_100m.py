"""End-to-end training driver: ~100M-param llama-family model.

Full pipeline: sharded synthetic data -> bubble-planned shardings ->
remat'd train step -> AdamW(ZeRO) -> atomic checkpoints -> straggler
detector.  Sized for a few hundred steps; on this CPU container use
``--steps 20 --seq 128`` for a quick run (the default 300-step run is the
real exercise on accelerators).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 20 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.models import lm


def config_100m():
    """~100M params, llama-shaped (yi-6b family scaled down)."""
    base = get_config("yi-6b")
    return dataclasses.replace(
        base, name="yi-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, d_ff=1792, vocab=32_000, head_dim=64,
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = config_100m()
    n = lm.count_params(cfg)
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    # reuse the production train driver with this config injected
    import repro.configs as configs_mod
    configs_mod.ARCHS.append("yi-100m")
    orig = configs_mod.get_config
    configs_mod.get_config = lambda a: cfg if a == "yi-100m" else orig(a)
    train_mod.get_config = configs_mod.get_config

    return train_mod.main([
        "--arch", "yi-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--lr", "3e-4",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
