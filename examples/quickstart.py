"""Quickstart: the bubble scheduler in 60 seconds.

1. Reproduce the paper's NovaScale result: simple vs bound vs bubbles.
2. Apply the same bubble machinery to a TPU mesh: derive a sharding plan
   for a real architecture from its bubble tree.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (BoundPolicy, BubblePolicy, SimplePolicy, Simulator,
                        novascale_16, stripes_workload)
from repro.core.planner import MeshAxis, plan_bubbles
from repro.configs import get_config
from repro.models import bubble_tree


def part1_paper():
    print("=" * 64)
    print("1. Thibault 2005, Table 2 — conduction on a 4-node ccNUMA")
    print("=" * 64)
    for name, cls, kw, grp in (
            ("simple (opportunist)", SimplePolicy, {"disorder": 4.0}, None),
            ("bound (hand-placed)", BoundPolicy, {}, None),
            ("bubbles (this paper)", BubblePolicy, {}, 4)):
        topo = novascale_16()
        root = stripes_workload(16, work=100.0, group=grp)
        sim = Simulator(topo, cls(topo, **kw), jitter=0.1,
                        mem_fraction=0.25, contention=0.5)
        r = sim.run(root, cycles=8)
        print(f"  {name:24s} speedup {r.speedup:5.2f} / 16 cpus")
    print("  (paper: 10.58 / 15.82 / 15.80 — portable bubbles == bound)\n")


def part2_tpu():
    print("=" * 64)
    print("2. Same idea, 512-chip TPU fleet — bubble tree -> sharding plan")
    print("=" * 64)
    axes = [MeshAxis("pod", 2), MeshAxis("data", 16), MeshAxis("model", 16)]
    for arch in ("deepseek-moe-16b", "grok-1-314b", "rwkv6-3b"):
        cfg = get_config(arch)
        tree = bubble_tree(cfg, "train_4k")
        plan = plan_bubbles(tree, axes)
        print(f"\n  {arch}:")
        for line in plan.pretty().splitlines()[1:]:
            print("  " + line)


if __name__ == "__main__":
    part1_paper()
    part2_tpu()
