"""Batched serving with bubble gang scheduling + regeneration.

Demonstrates the runtime-backed serving engine on a reduced config:
* SLA priorities (paper §3.3.2: a processor takes the highest-priority
  task even if less-prioritised ones are more local),
* gangs (shared-prefix request groups co-scheduled like Figure 1),
* regeneration of a stalled gang (paper §3.3.3) — its per-slot KV is
  parked and restored by the batched next-touch splice on re-admission,
* steal-driven admission + queue-depth rebalance (the SchedulerRuntime
  layer shared with the discrete simulator).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serving import ServingEngine


def main():
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = api.init(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=4, cache_len=64)
    rng = np.random.default_rng(0)

    print("submitting 3 SLA classes x 4 requests (two shared-prefix gangs)")
    for i in range(12):
        prompt = rng.integers(1, cfg.vocab, 12)
        gang = f"prefix{i % 2}" if i < 8 else None
        eng.submit(prompt, max_new_tokens=6, prio=i % 3, gang=gang)

    # backpressure on one gang mid-decode: its requests are pulled out (KV
    # parked), re-queued as a closed bubble, and resume later via the
    # batched splice — the serving next-touch path
    for _ in range(6):
        eng.step()
    pulled = eng.regenerate_gang("prefix1")
    print(f"regenerated gang prefix1: {pulled} requests parked")

    t0 = time.time()
    done = eng.run(max_steps=600)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    by_prio = {}
    for rank, r in enumerate(done):
        by_prio.setdefault(r.prio, []).append(rank)
    print(f"completed {len(done)}/12 requests, {toks} tokens, "
          f"{eng.steps} engine steps, {toks/max(dt,1e-9):.1f} tok/s")
    for p in sorted(by_prio, reverse=True):
        print(f"  prio {p}: completion ranks {by_prio[p]}")
    print("engine counters:", eng.counters())
    assert len(done) == 12
    assert pulled > 0 and eng.stats.kv_parks == pulled


if __name__ == "__main__":
    main()
