"""Benchmark aggregator: one module per paper table/figure + roofline.

Prints ``name,value,derived`` CSV rows (value unit depends on the bench:
us/call for Table 1, speedup for Table 2, gain-% for Fig 5, roofline step
ms for the dry-run table).

``--smoke`` runs a seconds-scale subset (conduction-only Table 2, small
Fig 5 sizes, no wall-clock Table 1 / roofline) — the CI sanity target.
"""

from __future__ import annotations

import os
import sys
import traceback

# make `benchmarks` and `repro` importable when invoked directly as
# `python benchmarks/run.py`, with or without PYTHONPATH=src
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    from benchmarks import fig5_fibonacci, table2_conduction

    if smoke:
        mods = [table2_conduction, fig5_fibonacci]
    else:
        from benchmarks import roofline, table1_cost
        mods = [table1_cost, table2_conduction, fig5_fibonacci, roofline]

    failed = 0
    for mod in mods:
        try:
            rows = mod.run(smoke=True) if smoke else mod.run()
            for name, v, d in rows:
                print(f"{name},{v:.4f},{d}")
        except Exception:
            traceback.print_exc()
            failed += 1
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
