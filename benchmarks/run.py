"""Benchmark aggregator: one module per paper table/figure + roofline.

Prints ``name,value,derived`` CSV rows (value unit depends on the bench:
us/call for Table 1, speedup for Table 2, gain-% for Fig 5, roofline step
ms for the dry-run table).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import fig5_fibonacci, roofline, table1_cost, \
        table2_conduction

    failed = 0
    for mod in (table1_cost, table2_conduction, fig5_fibonacci, roofline):
        try:
            for name, v, d in mod.run():
                print(f"{name},{v:.4f},{d}")
        except Exception:
            traceback.print_exc()
            failed += 1
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
