"""Benchmark aggregator: one module per paper table/figure + roofline.

Prints ``name,value,derived`` CSV rows (value unit depends on the bench:
us/call for Table 1, speedup for Table 2, gain-% for Fig 5, roofline step
ms for the dry-run table).

``--smoke`` runs a seconds-scale subset (conduction-only Table 2 with the
imbalanced + thrash stealing sections, small Fig 5 sizes, the stub-model
serving-gang rows, no wall-clock Table 1 / roofline) — the CI sanity
target — and writes a machine-readable ``BENCH_smoke.json`` (override the
path with ``--json PATH``; pass ``--json`` in non-smoke mode to capture
the full run).  Schema::

    {"schema": 1, "suite": "smoke"|"full",
     "rows": [{"name": "table2/thrash_adaptive", "value": 10.26,
               "kind": "speedup"|"gain_pct"|"latency"|"throughput"
                       |"us_per_call"|"step_ms",
               "derived": "...",
               "counters": {"steals": ..., "steals_by_level": {...},
                            "rebalances": ..., "steal_cost": ...}}]}

``counters`` is present on Table 2 rows only.  The ``bench-gate`` CI job
feeds this file to ``benchmarks/check_regression.py`` against the committed
``benchmarks/baseline_smoke.json`` — speedup rows regressing more than the
tolerance band fail the build.

The real-model serving lane (``serve_jax.py``, kind ``throughput``) is
deliberately NOT in this aggregator: it jits actual model steps, so it
lives in its own CI job (``jax-serve-gate``) with its own baseline
(``baseline_jax.json``) and a much wider band — see that module.
"""

from __future__ import annotations

import json
import os
import sys
import traceback

# make `benchmarks` and `repro` importable when invoked directly as
# `python benchmarks/run.py`, with or without PYTHONPATH=src
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

# value unit per benchmark module (JSON row "kind")
_KINDS = {"table1": "us_per_call", "table2": "speedup", "fig5": "gain_pct",
          "roofline": "step_ms", "serve": "speedup"}


def _json_path(argv: list[str], smoke: bool):
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 < len(argv) and not argv[i + 1].startswith("-"):
            return argv[i + 1]
        return "BENCH_smoke.json"
    return "BENCH_smoke.json" if smoke else None


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = _json_path(argv, smoke)
    from benchmarks import (fig5_fibonacci, serve_agentic, serve_elastic,
                            serve_gangs, serve_open_loop, table2_conduction)

    if smoke:
        mods = [table2_conduction, fig5_fibonacci, serve_gangs,
                serve_open_loop, serve_elastic, serve_agentic]
    else:
        from benchmarks import roofline, table1_cost
        mods = [table1_cost, table2_conduction, fig5_fibonacci, roofline,
                serve_gangs, serve_open_loop, serve_elastic, serve_agentic]

    failed = 0
    out_rows = []
    for mod in mods:
        try:
            rows = mod.run(smoke=True) if smoke else mod.run()
            for row in rows:
                name, v, d = row[:3]
                counters = row[3] if len(row) > 3 else None
                # optional per-row kind override (5th element) — the
                # open-loop bench mixes lower-is-better "latency" rows
                # into a prefix whose default kind is "speedup"
                kind = row[4] if len(row) > 4 else \
                    _KINDS.get(name.split("/")[0], "value")
                print(f"{name},{v:.4f},{d}")
                entry = {"name": name, "value": round(v, 6),
                         "kind": kind, "derived": d}
                if counters:
                    entry["counters"] = counters
                out_rows.append(entry)
        except Exception:
            traceback.print_exc()
            failed += 1
    if json_path and out_rows:
        with open(json_path, "w") as f:
            json.dump({"schema": 1, "suite": "smoke" if smoke else "full",
                       "rows": out_rows}, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path} ({len(out_rows)} rows)", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
