"""Open-loop serving benchmark: SLA-tiered scheduling vs hold-the-slot FIFO.

Every other ``serve/`` row drains a closed batch; this one replays an
**open-loop trace** (Poisson arrivals, heavy-tailed lengths, three SLA
classes — ``repro.serving.workload``) against two engines and gates the
suite's first *latency-percentile* rows:

* the **FIFO baseline** (``mode="admission"``, no ``sla_classes``): a
  request that gets a slot holds it to completion, admission is arrival
  order — every class queues behind whatever arrived first;
* the **SLA engine** (``sla_classes`` + ``preempt``): class priorities ride
  the covering-list walk (paper §3.3.2), a weighted deficit round-robin
  arbitrates admission so ``batch`` is never starved, long-runners demote
  (multilevel feedback), and an ``interactive`` backlog with no free slot
  parks a ``batch`` gang's KV (the PR 3 park/splice path) and restores it
  later without re-prefill.

Gated rows (both against the same trace, seed-pinned):

* ``serve/openloop_p99_ttft`` — the SLA engine's p99 TTFT for the
  ``interactive`` class, in engine steps.  **Lower is better** (kind
  ``latency``): the regression gate fails when the current value exceeds
  the baseline by more than the absolute tolerance band.
* ``serve/sla_preempt_goodput`` — goodput-under-SLA ratio, SLA engine over
  FIFO (completed requests whose TTFT met their contract SLO; both engines
  judged by the same SLOs).  Higher is better (kind ``speedup``).

Scheduling must never change *what* is decoded, only *when*: the two
engines' per-request streams are asserted identical, and a same-class
trace is replayed under two admission orders (per-step arrival order
reversed) to assert order-invariant streams.

Standalone entry point merges rows into the serve-gate JSON — run AFTER
``serve_gangs.py`` (whose merge replaces every ``serve/`` row) and it only
replaces its own rows::

    python benchmarks/serve_gangs.py --smoke --json BENCH_serve.json
    python benchmarks/serve_open_loop.py --smoke --json BENCH_serve.json
    python benchmarks/check_regression.py benchmarks/baseline_smoke.json \
        BENCH_serve.json --prefix serve/
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.core.bubble import reset_ids
from repro.serving import (SLA_CLASSES, ServingEngine, StubModelBackend,
                           drive, make_trace)

N_SLOTS = 16          # 2 hosts x 2 KV page groups x 4 slots
TRACE = dict(steps=160, rate=1.6, seed=0)   # ~1.1x the fleet's drain rate


def _engine(**kw) -> ServingEngine:
    reset_ids()       # fresh task ids: runs are independent and replayable
    return ServingEngine(None, None, n_slots=N_SLOTS, group=4, hosts=2,
                         backend=StubModelBackend(), **kw)


def _streams(eng: ServingEngine) -> dict:
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


def run(smoke: bool = False) -> list[tuple]:
    trace = make_trace(**TRACE)
    fifo = drive(_engine(mode="admission"), trace, max_steps=60000)
    sla = drive(_engine(sla_classes=SLA_CLASSES, preempt=True), trace,
                max_steps=60000)
    assert len(fifo.completed) == len(trace) == len(sla.completed), \
        (len(fifo.completed), len(sla.completed), len(trace))
    # scheduling (priorities, WDRR, preemption, park/splice) must never
    # change a decoded token — only when it lands
    assert _streams(fifo) == _streams(sla), "SLA scheduling changed output"
    # preemption actually exercised the park/splice path on this trace
    assert sla.stats.preemptions > 0 and sla.stats.preempt_parks > 0, \
        (sla.stats.preemptions, sla.stats.preempt_parks)

    # admission-order invariance for same-class traffic: same arrivals,
    # per-step submission order reversed -> identical streams per request
    same = [r for r in make_trace(**{**TRACE, "steps": 64, "seed": 1})
            if r.sla == "standard"]
    a = drive(_engine(sla_classes=SLA_CLASSES), list(same), max_steps=60000)
    rev = []
    for r in same:
        if rev and rev[-1][0] == r.step:
            rev[-1][1].insert(0, r)
        else:
            rev.append((r.step, [r]))
    flipped = [r for _, group in rev for r in group]
    b = drive(_engine(sla_classes=SLA_CLASSES), flipped, max_steps=60000)
    sa = sorted((tuple(r.prompt), tuple(r.out_tokens)) for r in a.completed)
    sb = sorted((tuple(r.prompt), tuple(r.out_tokens)) for r in b.completed)
    assert sa == sb, "admission order changed same-class streams"

    fs, ss = fifo.latency_summary(), sla.latency_summary()
    p99 = ss["classes"]["interactive"]["ttft_p99"]
    goodput = ss["goodput"]["frac"] / max(fs["goodput"]["frac"], 1e-9)
    c = sla.counters()
    c["fifo_steps"] = fifo.steps
    c["fifo_goodput"] = round(fs["goodput"]["frac"], 6)
    c["sla_goodput"] = round(ss["goodput"]["frac"], 6)
    c["fifo_interactive_p99_ttft"] = fs["classes"]["interactive"]["ttft_p99"]
    c["interactive_p50_ttft"] = ss["classes"]["interactive"]["ttft_p50"]
    c["batch_p99_ttft"] = ss["classes"]["batch"]["ttft_p99"]
    rows = [
        ("serve/openloop_p99_ttft", p99,
         f"interactive p99 TTFT {p99} steps (fifo "
         f"{c['fifo_interactive_p99_ttft']}) over {len(trace)} arrivals",
         c, "latency"),
        ("serve/sla_preempt_goodput", goodput,
         f"goodput {c['fifo_goodput']}->{c['sla_goodput']} "
         f"preemptions={c['preemptions']} parks={c['preempt_parks']}",
         c, "speedup"),
    ]
    return rows


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Merge this module's rows into a schema-1 BENCH json, replacing ONLY
    rows of the same names (``serve_gangs.merge_into_json`` replaces every
    ``serve/`` row, so this one must run after it and touch only its
    own)."""
    doc = {"schema": 1, "suite": "smoke", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        mine = {name for name, *_ in rows}
        doc["rows"] = [r for r in doc["rows"] if r["name"] not in mine]
    for name, v, d, counters, kind in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": kind, "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} open-loop rows into {path}",
          file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_smoke.json"
    elif smoke:
        json_path = "BENCH_smoke.json"
    rows = run(smoke=smoke)
    for name, v, d, _, kind in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
