"""Roofline bench: aggregates the dry-run cells into the §Roofline table.

Reads ``benchmarks/results/dryrun/*.json`` (written by
``repro.launch.dryrun``).  Emits one row per (arch × shape × mesh):
roofline step time with the dominant term named, plus strategy-comparison
rows (simple/bound/bubbles) for any cells lowered with multiple strategies
— the fleet-scale analogue of the paper's Table 2.
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells() -> list[dict]:
    return [json.load(open(f)) for f in sorted(glob.glob(str(RESULTS / "*.json")))]


def run() -> list[tuple[str, float, str]]:
    rows = []
    cells = load_cells()
    if not cells:
        return [("roofline/no_cells", 0.0,
                 "run: python -m repro.launch.dryrun")]
    for d in cells:
        r = d["roofline"]
        pods = "2pod" if "pod" in d["mesh"] else "1pod"
        name = f"roofline/{d['arch']}/{d['shape']}/{pods}/{d['strategy']}"
        derived = (f"{r['bottleneck']}-bound mfu={r['mfu_at_roofline']:.3f} "
                   f"useful={r['useful_fraction']:.2f} "
                   f"fits={d['memory']['fits']}")
        rows.append((name, r["t_step_s"] * 1e3, derived))

    # strategy comparisons (Table-2 analogue) where present
    by_cell: dict = {}
    for d in cells:
        pods = "2pod" if "pod" in d["mesh"] else "1pod"
        by_cell.setdefault((d["arch"], d["shape"], pods), {})[
            d["strategy"]] = d["roofline"]["t_step_s"]
    for (arch, shape, pods), strat in by_cell.items():
        if len(strat) > 1 and "bubbles" in strat:
            for s, t in strat.items():
                if s == "bubbles":
                    continue
                rows.append((
                    f"roofline_strategy/{arch}/{shape}/{pods}/{s}_vs_bubbles",
                    t / strat["bubbles"],
                    f"step-time ratio {s}/bubbles (>1 = bubbles faster)"))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.3f},{d}")
