"""Docs-reference gate: code references in the docs must resolve.

Usage::

    python benchmarks/check_docs.py            # checks README.md + docs/*.md

Documentation that points into the tree rots silently: a rename leaves
``docs/ARCHITECTURE.md`` recommending a module that no longer exists and
nothing fails.  This checker (grep-based, zero imports of repro itself —
it must run even when the tree is broken) extracts every backtick span
from `README.md` and `docs/*.md` and verifies the ones that *look like*
code references:

* **paths** (contain ``/``): must exist relative to the repo root, OR
  appear verbatim somewhere in the source corpus — the latter legitimises
  non-file identifiers like benchmark row names (``serve/..._speedup``)
  which are spelled path-ish but live as strings in ``benchmarks/``;
* **dotted ``repro.*`` references** (``repro.core.runtime.SchedulerRuntime``):
  the longest importable prefix must resolve under ``src/`` and any
  leftover attribute parts must appear as words in the resolved module
  (or anywhere under the resolved package);
* **bare dotted identifiers** (``ServingEngine``, ``EngineStats.host_decode_steps``,
  ``prefill_wave()``): every dotted component must appear as a word
  somewhere in the source corpus (``src/``, ``tests/``, ``benchmarks/``,
  ``Makefile``, CI config).

Everything else — shell lines, flags, expressions, prose in backticks —
is deliberately ignored: the gate exists to catch renamed files and
symbols, not to parse English.  Exit 0 clean, 1 with unresolved
references listed, 2 on usage/IO error.  Wired into ``make lint`` and the
CI lint job.
"""

from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_GLOBS = ("README.md", "docs/*.md")
CORPUS_GLOBS = ("src/**/*.py", "tests/*.py", "benchmarks/*.py",
                "examples/*.py", "Makefile", ".github/workflows/*.yml")

SPAN_RE = re.compile(r"`([^`\n]+)`")
IDENT_RE = re.compile(r"[A-Za-z_]\w*(\.[A-Za-z_]\w*)*")
WORD_CACHE: dict[str, bool] = {}


def _corpus() -> str:
    parts = []
    for pat in CORPUS_GLOBS:
        for path in sorted(glob.glob(os.path.join(ROOT, pat),
                                     recursive=True)):
            try:
                with open(path, encoding="utf-8", errors="replace") as f:
                    parts.append(f.read())
            except OSError:
                pass
    return "\n".join(parts)


def _word_in_corpus(corpus: str, word: str) -> bool:
    hit = WORD_CACHE.get(word)
    if hit is None:
        hit = re.search(rf"\b{re.escape(word)}\b", corpus) is not None
        WORD_CACHE[word] = hit
    return hit


def _check_repro_ref(ref: str, corpus: str) -> str | None:
    """``repro.a.b[.Symbol...]``: resolve the module prefix under src/,
    then require leftover parts to appear in the resolved file/package."""
    parts = ref.split(".")
    base = os.path.join(ROOT, "src")
    consumed = 0
    resolved = None                      # file or package dir
    for i, part in enumerate(parts):
        cand_dir = os.path.join(base, part)
        cand_py = cand_dir + ".py"
        if os.path.isdir(cand_dir):
            base, resolved, consumed = cand_dir, cand_dir, i + 1
        elif os.path.isfile(cand_py):
            resolved, consumed = cand_py, i + 1
            break
        else:
            break
    if resolved is None or consumed < 2:
        return f"unresolvable module prefix (looked under src/): {ref}"
    leftover = parts[consumed:]
    if not leftover:
        return None
    if os.path.isdir(resolved):
        text = _corpus_of_dir(resolved)
    else:
        with open(resolved, encoding="utf-8", errors="replace") as f:
            text = f.read()
    for sym in leftover:
        if re.search(rf"\b{re.escape(sym)}\b", text) is None:
            where = os.path.relpath(resolved, ROOT)
            return f"symbol {sym!r} not found in {where} (from {ref})"
    return None


_DIR_CACHE: dict[str, str] = {}


def _corpus_of_dir(path: str) -> str:
    text = _DIR_CACHE.get(path)
    if text is None:
        parts = []
        for py in sorted(glob.glob(os.path.join(path, "**", "*.py"),
                                   recursive=True)):
            with open(py, encoding="utf-8", errors="replace") as f:
                parts.append(f.read())
        text = _DIR_CACHE[path] = "\n".join(parts)
    return text


def check_span(span: str, corpus: str) -> str | None:
    """Return an error string for a broken reference, None when the span
    is fine or not a code reference at all."""
    s = span.strip()
    if not s or s.startswith("-") or "*" in s or "<" in s or "{" in s:
        return None
    first = s.split()[0].rstrip(",.:;")
    if "/" in first:
        if first.startswith(("http://", "https://", "~")):
            return None
        if os.path.exists(os.path.join(ROOT, first)):
            return None
        if _word_in_corpus(corpus, first) or first in corpus:
            return None                  # row names etc., spelled path-ish
        return f"path (or corpus string) not found: {first}"
    if len(s.split()) > 1:
        return None                      # shell line / prose
    bare = s[:-2] if s.endswith("()") else s
    bare = bare.rstrip(",.:;")
    m = IDENT_RE.fullmatch(bare)
    if m is None:
        return None                      # expression, not an identifier
    if bare.startswith("repro."):
        return _check_repro_ref(bare, corpus)
    for token in bare.split("."):
        if not _word_in_corpus(corpus, token):
            return f"identifier {token!r} (from `{span}`) not found in " \
                   "the source corpus"
    return None


def main() -> int:
    docs = []
    for pat in DOC_GLOBS:
        docs.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    if not docs:
        print("error: no docs found (README.md / docs/*.md)")
        return 2
    corpus = _corpus()
    failures = []
    n_spans = 0
    for doc in docs:
        with open(doc, encoding="utf-8") as f:
            text = f.read()
        # fenced code blocks are command transcripts, not references
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for match in SPAN_RE.finditer(text):
            n_spans += 1
            err = check_span(match.group(1), corpus)
            if err:
                line = text[:match.start()].count("\n") + 1
                failures.append(
                    f"{os.path.relpath(doc, ROOT)}:~{line}: {err}")
    print(f"{len(docs)} docs, {n_spans} backtick spans checked, "
          f"{len(failures)} unresolved")
    for f in failures:
        print(f"BROKEN REF: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
