"""Agentic serving benchmark: sleep-and-release vs hold-the-slot.

Two gated rows, both replaying the same agentic trace (multi-turn chat
sessions with heavy-tailed tool-call think gaps, a share of gang sessions
sharing one schedule) against a contended 2-host fleet:

* ``serve/agentic_slot_util_speedup`` — the **sleep** engine
  (``agentic_sleep=True``) parks a session's KV at each tool call and
  frees the slot for the backlog, waking it later near its home page
  group (wake-affinity quote); the **hold** baseline
  (``agentic_sleep=False``) keeps the slot occupied while the session
  thinks.  The row is hold steps over sleep steps to drain the identical
  trace (higher is better, kind ``speedup``) — under contention the
  sleeping sessions are where all the capacity headroom lives.  Both
  arms must complete every request with **token-identical streams**
  (sleeping may never change what is decoded, only when) and the row
  asserts the >= 1.2x acceptance floor.

* ``serve/agentic_wake_latency`` — the p99 wake-to-token latency of the
  sleep arm (tool response to first post-wake token, pooled over SLA
  classes; lower is better, kind ``latency``).  Judged from the wake
  ledger, which is distinct from TTFT — TTFT stays a first-admission
  contract.

Standalone entry point merges rows into the serve-gate JSON — run AFTER
``serve_gangs.py`` (whose merge replaces every ``serve/`` row); like
``serve_open_loop.py`` / ``serve_elastic.py`` it only replaces its own
rows::

    python benchmarks/serve_gangs.py --smoke --json BENCH_serve.json
    python benchmarks/serve_open_loop.py --smoke --json BENCH_serve.json
    python benchmarks/serve_elastic.py --smoke --json BENCH_serve.json
    python benchmarks/serve_agentic.py --smoke --json BENCH_serve.json
    python benchmarks/check_regression.py benchmarks/baseline_smoke.json \
        BENCH_serve.json --prefix serve/
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.core.bubble import reset_ids
from repro.serving import (SERVE_COST, ServingEngine, StubModelBackend,
                           drive, make_agentic_trace, percentile)

N_SLOTS = 16          # 2 hosts x 2 KV page groups x 4 slots
TRACE = dict(steps=64, rate=1.1, seed=7, max_turns=4,
             think=(2.2, 0.7, 4, 40), gang_share=0.3, gang_size=4)


def _engine(**kw) -> ServingEngine:
    reset_ids()
    return ServingEngine(None, None, n_slots=N_SLOTS, group=4, hosts=2,
                         backend=StubModelBackend(), cost_model=SERVE_COST,
                         **kw)


def _streams(eng: ServingEngine) -> dict:
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


def slot_util_row(trace) -> tuple[tuple, ServingEngine]:
    sleep = drive(_engine(agentic_sleep=True), list(trace))
    hold = drive(_engine(agentic_sleep=False), list(trace))
    got_s, got_h = _streams(sleep), _streams(hold)
    assert len(got_s) == len(trace), \
        f"sleep arm lost requests ({len(got_s)}/{len(trace)})"
    assert len(got_h) == len(trace), \
        f"hold arm lost requests ({len(got_h)}/{len(trace)})"
    assert got_s == got_h, "sleep and hold decode streams diverged"
    cs, ch = sleep.counters(), hold.counters()
    assert cs["sleeps"] > 0 and cs["wakes"] == cs["sleeps"], cs
    assert ch["holds"] > 0 and ch["hold_slot_steps"] > 0, ch
    ratio = hold.steps / sleep.steps
    assert ratio >= 1.2, \
        f"slot-util speedup {ratio:.3f} below the 1.2x acceptance floor"
    c = dict(cs)
    c["hold_steps"] = hold.steps
    c["hold_slot_steps"] = ch["hold_slot_steps"]
    row = ("serve/agentic_slot_util_speedup", ratio,
           f"drain {hold.steps}->{sleep.steps} steps: {cs['sleeps']} sleeps "
           f"freed slots the hold baseline idled for "
           f"{ch['hold_slot_steps']} slot-steps "
           f"({cs['wake_home']} home / {cs['wake_away']} away wakes), "
           "streams identical", c, "speedup")
    return row, sleep


def wake_latency_row(sleep: ServingEngine) -> tuple:
    lat = sleep.latency_summary()["classes"]
    pooled = [w for rows in sleep._wake_lat.values() for w in rows]
    assert pooled, "sleep arm recorded no wake-to-token samples"
    p99 = percentile(pooled, 99)
    per_cls = {f"wake_p99_{n}": r["wake_p99"] for n, r in lat.items()
               if r["wakes"]}
    per_cls["wake_samples"] = len(pooled)
    per_cls["wake_p50"] = percentile(pooled, 50)
    return ("serve/agentic_wake_latency", p99,
            f"p99 wake-to-token {p99:.1f} steps over {len(pooled)} wakes "
            f"(p50 {per_cls['wake_p50']:.1f})", per_cls, "latency")


def run(smoke: bool = False) -> list[tuple]:
    trace = make_agentic_trace(**TRACE)
    assert any(r.tool_calls for r in trace)
    row, sleep = slot_util_row(trace)
    return [row, wake_latency_row(sleep)]


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Replace only this module's rows (``serve_gangs`` owns the wholesale
    ``serve/`` replace; this must run after it)."""
    doc = {"schema": 1, "suite": "smoke", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        mine = {name for name, *_ in rows}
        doc["rows"] = [r for r in doc["rows"] if r["name"] not in mine]
    for name, v, d, counters, kind in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": kind, "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} agentic rows into {path}", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_smoke.json"
    elif smoke:
        json_path = "BENCH_smoke.json"
    rows = run(smoke=smoke)
    for name, v, d, _, kind in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
