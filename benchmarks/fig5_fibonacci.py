"""Paper Figure 5: gain from expressing divide-and-conquer recursion as
bubbles, vs thread count, on both evaluation machines.

Paper: Bi-Xeon HT stabilises at 30-40% gain from 16 threads; NUMA 4x4
Itanium II reaches 40% at 32 threads and up to 80% at 512.
Output CSV: name,us_per_call(gain %),derived
"""

from __future__ import annotations

from repro.core import (BubblePolicy, SimplePolicy, Simulator, bi_xeon_ht,
                        fibonacci_workload, novascale_16)


def gain(n_threads: int, topo_fn, gs: int, mem: float = 0.6) -> float:
    ts = {}
    for with_b in (False, True):
        topo = topo_fn()
        pol = (BubblePolicy(topo) if with_b
               else SimplePolicy(topo, disorder=4.0))
        root = fibonacci_workload(n_threads, with_bubbles=with_b,
                                  group_size=gs)
        r = Simulator(topo, pol, mem_fraction=mem, contention=0.5).run(root)
        ts[with_b] = r.time
    return (ts[False] - ts[True]) / ts[False] * 100


def run() -> list[tuple[str, float, str]]:
    rows = []
    for n in (16, 32, 128, 512):
        g = gain(n, novascale_16, gs=4)
        paper = {32: "paper ~40%", 512: "paper up to 80%"}.get(n, "")
        rows.append((f"fig5/numa4x4_n{n}", g, paper))
    for n in (8, 16, 64):
        g = gain(n, bi_xeon_ht, gs=2)
        rows.append((f"fig5/bixeon_n{n}", g,
                     "paper 30-40% stabilised" if n >= 16 else ""))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.1f},{d}")
