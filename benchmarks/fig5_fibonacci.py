"""Paper Figure 5: gain from expressing divide-and-conquer recursion as
bubbles, vs thread count, on both evaluation machines.

Paper: Bi-Xeon HT stabilises at 30-40% gain from 16 threads; NUMA 4x4
Itanium II reaches 40% at 32 threads and up to 80% at 512.

The ``*_steal`` rows rerun the bubble side with :class:`StealPolicy`
(hierarchical whole-bubble stealing + next-touch migration) — the deep
fibonacci tree leaves closed sub-bubbles on queues, exactly the loot the
§3.3.3 steal pass is for.

Output CSV: name,us_per_call(gain %),derived
"""

from __future__ import annotations

from repro.core import (AdaptivePolicy, BubblePolicy, SimplePolicy,
                        StealPolicy, Simulator, bi_xeon_ht,
                        fibonacci_workload, novascale_16, reset_ids)


def _time_one(n_threads: int, topo_fn, gs: int, mem: float,
              policy_cls) -> float:
    reset_ids()
    topo = topo_fn()
    with_b = policy_cls is not SimplePolicy
    pol = (policy_cls(topo) if with_b
           else SimplePolicy(topo, disorder=4.0))
    root = fibonacci_workload(n_threads, with_bubbles=with_b, group_size=gs)
    return Simulator(topo, pol, mem_fraction=mem, contention=0.5).run(root).time


def gain(n_threads: int, topo_fn, gs: int, mem: float = 0.6,
         bubble_cls=BubblePolicy, baseline: float = None) -> float:
    """Percent gain of the bubbled run over the flat SimplePolicy run.

    ``baseline`` reuses an already-measured flat time (runs are
    deterministic, so the 512-thread baseline need not be simulated once
    per bubble policy)."""
    if baseline is None:
        baseline = _time_one(n_threads, topo_fn, gs, mem, SimplePolicy)
    t = _time_one(n_threads, topo_fn, gs, mem, bubble_cls)
    return (baseline - t) / baseline * 100


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    numa_ns = (16, 32) if smoke else (16, 32, 128, 512)
    xeon_ns = (8,) if smoke else (8, 16, 64)
    for n in numa_ns:
        base = _time_one(n, novascale_16, 4, 0.6, SimplePolicy)
        g = gain(n, novascale_16, gs=4, baseline=base)
        paper = {32: "paper ~40%", 512: "paper up to 80%"}.get(n, "")
        rows.append((f"fig5/numa4x4_n{n}", g, paper))
        gsteal = gain(n, novascale_16, gs=4, bubble_cls=StealPolicy,
                      baseline=base)
        rows.append((f"fig5/numa4x4_n{n}_steal", gsteal,
                     "bubbles + steal + next-touch"))
        gadapt = gain(n, novascale_16, gs=4, bubble_cls=AdaptivePolicy,
                      baseline=base)
        rows.append((f"fig5/numa4x4_n{n}_adaptive", gadapt,
                     "= steal under zero cost (cost-benefit trigger idle)"))
    for n in xeon_ns:
        g = gain(n, bi_xeon_ht, gs=2)
        rows.append((f"fig5/bixeon_n{n}", g,
                     "paper 30-40% stabilised" if n >= 16 else ""))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.1f},{d}")
