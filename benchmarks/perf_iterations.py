import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: the three chosen cells, one iteration per run.

Each invocation lowers ONE (cell, variant) and appends the result to
benchmarks/results/perf_iterations/.  EXPERIMENTS.md §Perf is written from
these JSONs.

  python -m benchmarks.perf_iterations --list
  python -m benchmarks.perf_iterations --run yi_sp
"""

import argparse
import json
from pathlib import Path

import jax

from repro.configs import get_config
from repro.launch import dryrun
from repro.launch.mesh import make_mesh, make_production_mesh

OUT = Path(__file__).resolve().parent / "results" / "perf_iterations"

def prod():
    return make_production_mesh()

def ep2d():
    return make_mesh((16, 8, 2), ("data", "expert", "ffn"))

# (name, arch, shape, strategy, mesh factory)
ITERS = {
    # --- cell 1: yi-6b train_4k (representative dense; collective-bound) ---
    "yi_sp":        ("yi-6b", "train_4k", "bubbles_sp", prod),
    "yi_fsdp_sp":   ("yi-6b", "train_4k", "fsdp_sp", prod),
    "yi_simple":    ("yi-6b", "train_4k", "simple", prod),
    "yi_bound":     ("yi-6b", "train_4k", "bound", prod),
    # --- cell 2: grok-1-314b train_4k (worst roofline fraction) ---
    "grok_ep2d":    ("grok-1-314b", "train_4k", "ep2d", ep2d),
    "grok_ep2d_sp": ("grok-1-314b", "train_4k", "ep2d_sp", ep2d),
    "grok_fsdp_sp": ("grok-1-314b", "train_4k", "fsdp_sp", prod),
    "grok_bfsdp_sp": ("grok-1-314b", "train_4k", "bubbles_fsdp_sp", prod),
    "dsk_final": ("deepseek-moe-16b", "train_4k", "bubbles", prod),
    "grok_gather": ("grok-1-314b", "train_4k", "bubbles", prod),
    "grok_gather_sp": ("grok-1-314b", "train_4k", "bubbles_sp", prod),
    "dsk_gather": ("deepseek-moe-16b", "train_4k", "bubbles", prod),
    "dsk_prefill_gather": ("deepseek-moe-16b", "prefill_32k", "bubbles", prod),
    "grok_decode_gather": ("grok-1-314b", "decode_32k", "bubbles", prod),
    "dsk_prefill_final": ("deepseek-moe-16b", "prefill_32k", "bubbles", prod),
    "grok_decode_cap": ("grok-1-314b", "decode_32k", "bubbles", prod),
    "grok_decode_ep2d": ("grok-1-314b", "decode_32k", "ep2d", ep2d),
    # --- cell 3: deepseek prefill_32k (most collective-bound serving) ---
    "dsk_train_shared": ("deepseek-moe-16b", "train_4k", "bubbles", prod),
    "dsk_train_sp": ("deepseek-moe-16b", "train_4k", "bubbles_sp", prod),
    "dsk_prefill_shared": ("deepseek-moe-16b", "prefill_32k", "bubbles", prod),
    "dsk_prefill_sp": ("deepseek-moe-16b", "prefill_32k", "bubbles_sp", prod),
    "dsk_ep2d_sp":  ("deepseek-moe-16b", "train_4k", "ep2d_sp",
                     lambda: make_mesh((4, 32, 2), ("data", "expert", "ffn"))),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or not args.run:
        for k, (a, s, st, _) in ITERS.items():
            print(f"{k:20s} {a} x {s} [{st}]")
        return
    name = args.run
    arch, shape, strategy, mesh_fn = ITERS[name]
    cfg = get_config(arch)
    mesh = mesh_fn()
    print(f"RUN {name}: {arch} x {shape} [{strategy}] "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    out = dryrun.run_cell(cfg, shape, mesh, strategy)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
