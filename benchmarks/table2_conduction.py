"""Paper Table 2: conduction/advection speedups on the simulated NovaScale.

Reproduces the simple / bound / bubbles comparison (16 Itanium II, 4 NUMA
nodes, NUMA factor 3) for the two §5.2 applications: heat conduction
(mem_fraction 0.25) and advection (0.4 — more memory-bound per unit work).

Paper values: conduction 10.58 / 15.82 / 15.80; advection 9.11/12.40/12.40.

Beyond the paper's balanced stripes, an **imbalanced** section runs an
uneven bubble tree (groups of 2..12 stripes, node burst hints, skewed
stripe work) — the §3.3.3 work-stealing scenario.  Rows compare stealing
off (``bubbles_nosteal``: idle nodes stay idle), stealing with first-touch
memory (``bubbles``), and stealing + next-touch migration (``steal``).

Output CSV: name,us_per_call(speedup),derived
"""

from __future__ import annotations

from repro.core import (BoundPolicy, BubblePolicy, PerCpuPolicy, SimplePolicy,
                        Simulator, StealPolicy, imbalanced_stripes_workload,
                        novascale_16, reset_ids, stripes_workload)

PAPER = {
    ("conduction", "simple"): 10.58, ("conduction", "bound"): 15.82,
    ("conduction", "bubbles"): 15.80,
    ("advection", "simple"): 9.11, ("advection", "bound"): 12.40,
    ("advection", "bubbles"): 12.40,
}

def _run(policy_cls, mem, group=None, root_fn=None, **kw):
    reset_ids()
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    root = root_fn() if root_fn else \
        stripes_workload(n_threads=16, work=100.0, group=group)
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=8)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    apps = (("conduction", 0.25),) if smoke else \
        (("conduction", 0.25), ("advection", 0.4))
    for app, mem in apps:
        for name, cls, kw, grp in (
                ("simple", SimplePolicy, {"disorder": 4.0}, None),
                ("percpu", PerCpuPolicy, {}, None),
                ("bound", BoundPolicy, {}, None),
                ("bubbles", BubblePolicy, {}, 4),
                ("steal", StealPolicy, {}, 4)):
            s = _run(cls, mem, group=grp, **kw).speedup
            paper = PAPER.get((app, name))
            rows.append((f"table2/{app}_{name}", s,
                         f"paper: {paper}" if paper else
                         ("= bubbles on balanced load" if name == "steal"
                          else "extra baseline")))
    # -- imbalanced bubble tree: the work-stealing rows ----------------------
    for name, cls, kw in (
            ("simple", SimplePolicy, {"disorder": 4.0}),
            ("bound", BoundPolicy, {}),
            ("bubbles_nosteal", BubblePolicy, {"steal": False}),
            ("bubbles", BubblePolicy, {}),
            ("steal", StealPolicy, {})):
        flat = cls not in (BubblePolicy, StealPolicy)
        r = _run(cls, 0.25,
                 root_fn=lambda flat=flat: imbalanced_stripes_workload(
                     flat=flat), **kw)
        rows.append((f"table2/imbalanced_{name}", r.speedup,
                     f"time={r.time:.0f} steals={r.extra['steals']}"
                     f" data_migrations={r.data_migrations}"))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.2f},{d}")
