"""Paper Table 2: conduction/advection speedups on the simulated NovaScale.

Reproduces the simple / bound / bubbles comparison (16 Itanium II, 4 NUMA
nodes, NUMA factor 3) for the two §5.2 applications: heat conduction
(mem_fraction 0.25) and advection (0.4 — more memory-bound per unit work).

Paper values: conduction 10.58 / 15.82 / 15.80; advection 9.11/12.40/12.40.
Output CSV: name,us_per_call(speedup),derived
"""

from __future__ import annotations

from repro.core import (BoundPolicy, BubblePolicy, PerCpuPolicy, SimplePolicy,
                        Simulator, novascale_16, stripes_workload)

PAPER = {
    ("conduction", "simple"): 10.58, ("conduction", "bound"): 15.82,
    ("conduction", "bubbles"): 15.80,
    ("advection", "simple"): 9.11, ("advection", "bound"): 12.40,
    ("advection", "bubbles"): 12.40,
}


def _run(policy_cls, mem, group=None, **kw):
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    root = stripes_workload(16, work=100.0, group=group)
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=8).speedup


def run() -> list[tuple[str, float, str]]:
    rows = []
    for app, mem in (("conduction", 0.25), ("advection", 0.4)):
        for name, cls, kw, grp in (
                ("simple", SimplePolicy, {"disorder": 4.0}, None),
                ("percpu", PerCpuPolicy, {}, None),
                ("bound", BoundPolicy, {}, None),
                ("bubbles", BubblePolicy, {}, 4)):
            s = _run(cls, mem, group=grp, **kw)
            paper = PAPER.get((app, name))
            rows.append((f"table2/{app}_{name}", s,
                         f"paper: {paper}" if paper else "extra baseline"))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.2f},{d}")
