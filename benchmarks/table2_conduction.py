"""Paper Table 2: conduction/advection speedups on the simulated NovaScale.

Reproduces the simple / bound / bubbles comparison (16 Itanium II, 4 NUMA
nodes, NUMA factor 3) for the two §5.2 applications: heat conduction
(mem_fraction 0.25) and advection (0.4 — more memory-bound per unit work).

Paper values: conduction 10.58 / 15.82 / 15.80; advection 9.11/12.40/12.40.

Beyond the paper's balanced stripes, an **imbalanced** section runs an
uneven bubble tree (groups of 2..12 stripes, node burst hints, skewed
stripe work) — the §3.3.3 work-stealing scenario.  Rows compare stealing
off (``bubbles_nosteal``: idle nodes stay idle), stealing with first-touch
memory (``bubbles``), stealing + next-touch migration (``steal``), and the
cost-aware ``adaptive`` policy (which, with stealing free, must match
``steal``).

A **thrash** section runs the thrash-prone tree (24 singleton bubbles + one
24-thread bubble, small skewed stripes) under a nonzero
:class:`~repro.core.scheduler.StealCostModel`, so every steal pays a remote
lock/latency penalty rivalling the stripes' own work.  Here reactive
stealing thrashes — ``adaptive``'s proactive re-gather + re-spread is the
row that must win (ISSUE 2 acceptance: >= 1.2x over plain ``steal``).

Output CSV: name,us_per_call(speedup),derived.  Rows carry a counters dict
(steals, per-level steal histogram, rebalances, cost paid) consumed by
``run.py --smoke``'s BENCH_smoke.json and rendered per level by
``render_experiments.py``.
"""

from __future__ import annotations

from repro.core import (THRASH_COST, AdaptivePolicy, BoundPolicy,
                        BubblePolicy, PerCpuPolicy, SimplePolicy, Simulator,
                        StealPolicy, imbalanced_stripes_workload, novascale_16,
                        reset_ids, stripes_workload, thrash_stripes_workload)
from repro.core.trace import Tracer

PAPER = {
    ("conduction", "simple"): 10.58, ("conduction", "bound"): 15.82,
    ("conduction", "bubbles"): 15.80,
    ("advection", "simple"): 9.11, ("advection", "bound"): 12.40,
    ("advection", "bubbles"): 12.40,
}

def _run(policy_cls, mem, group=None, root_fn=None, **kw):
    reset_ids()
    topo = novascale_16()
    pol = policy_cls(topo, **kw)
    # trace bubble-family runs so steal/rebalance behaviour is reported per
    # level, not just counted
    tracer = Tracer(pol.sched) if hasattr(pol, "sched") else None
    root = root_fn() if root_fn else \
        stripes_workload(n_threads=16, work=100.0, group=group)
    sim = Simulator(topo, pol, jitter=0.1, mem_fraction=mem, contention=0.5)
    return sim.run(root, cycles=8), tracer


def _counters(r, tracer) -> dict:
    c = {"time": round(r.time, 4), "speedup": round(r.speedup, 4),
         "steals": r.extra.get("steals", 0),
         "steal_attempts": r.extra.get("steal_attempts", 0),
         "steal_cost": round(r.extra.get("steal_cost", 0.0), 4),
         "rebalances": r.extra.get("rebalances", 0),
         "rebalance_moves": r.extra.get("rebalance_moves", 0),
         "rebalance_cost": round(r.extra.get("rebalance_cost", 0.0), 4),
         "data_migrations": r.data_migrations}
    if tracer is not None:
        c["steals_by_level"] = tracer.steals_by_level()
    return c


def run(smoke: bool = False) -> list[tuple]:
    rows: list[tuple] = []
    apps = (("conduction", 0.25),) if smoke else \
        (("conduction", 0.25), ("advection", 0.4))
    for app, mem in apps:
        for name, cls, kw, grp in (
                ("simple", SimplePolicy, {"disorder": 4.0}, None),
                ("percpu", PerCpuPolicy, {}, None),
                ("bound", BoundPolicy, {}, None),
                ("bubbles", BubblePolicy, {}, 4),
                ("steal", StealPolicy, {}, 4)):
            r, tracer = _run(cls, mem, group=grp, **kw)
            paper = PAPER.get((app, name))
            rows.append((f"table2/{app}_{name}", r.speedup,
                         f"paper: {paper}" if paper else
                         ("= bubbles on balanced load" if name == "steal"
                          else "extra baseline"),
                         _counters(r, tracer)))
    # -- imbalanced bubble tree: the work-stealing rows ----------------------
    bubbly = (BubblePolicy, StealPolicy, AdaptivePolicy)
    for name, cls, kw in (
            ("simple", SimplePolicy, {"disorder": 4.0}),
            ("bound", BoundPolicy, {}),
            ("bubbles_nosteal", BubblePolicy, {"steal": False}),
            ("bubbles", BubblePolicy, {}),
            ("steal", StealPolicy, {}),
            ("adaptive", AdaptivePolicy, {})):
        flat = cls not in bubbly
        r, tracer = _run(cls, 0.25,
                         root_fn=lambda flat=flat: imbalanced_stripes_workload(
                             flat=flat), **kw)
        rows.append((f"table2/imbalanced_{name}", r.speedup,
                     f"time={r.time:.0f} steals={r.extra['steals']}"
                     f" data_migrations={r.data_migrations}",
                     _counters(r, tracer)))
    # -- thrash-prone tree under steal cost: the adaptive rows ---------------
    for name, cls, kw in (
            ("bubbles_nosteal", BubblePolicy, {"steal": False}),
            ("steal", StealPolicy, {"cost_model": THRASH_COST}),
            ("adaptive", AdaptivePolicy, {"cost_model": THRASH_COST})):
        flat = cls not in bubbly
        r, tracer = _run(cls, 0.25,
                         root_fn=lambda flat=flat: thrash_stripes_workload(
                             flat=flat), **kw)
        rows.append((f"table2/thrash_{name}", r.speedup,
                     f"time={r.time:.0f} steals={r.extra['steals']}"
                     f" cost={r.extra['steal_cost']:.0f}"
                     f" rebalances={r.extra['rebalances']}"
                     f" rebalance_cost={r.extra['rebalance_cost']:.0f}",
                     _counters(r, tracer)))
    return rows


if __name__ == "__main__":
    for row in run():
        name, v, d = row[:3]
        print(f"{name},{v:.2f},{d}")
