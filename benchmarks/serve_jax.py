"""Real-model serving benchmark: the engine driving the jax model zoo.

``serve_gangs.py`` measures the scheduler stack over a stub model; this
benchmark closes the loop with the *real* decode path — reduced model-zoo
configs (CPU-runnable: tiny dims, full architecture) behind the two jax
backends:

* ``JaxModelBackend`` — dense KV, batch axis in the cache tensors, a KV
  migration is a per-layer tensor copy;
* ``PagedJaxModelBackend`` — KV in per-layer page pools behind one block
  table per host batch, a KV migration is a block-table edit.

Two architectures cover both state families:

* **transformer** (``yi-6b`` reduced): GQA attention, the paged layout's
  reason to exist.  The trace regenerates gangs on a fixed cadence, so
  parked requests re-splice mid-flight — on the paged backend those are
  pure metadata writes, and the single-host trace asserts ZERO KV-pool
  copies (``pool_copies == 0``) while every stream matches the dense
  backend token for token.
* **rwkv** (``rwkv6-3b`` reduced): attention-free, O(1) recurrent state.
  The paged backend degenerates to the explicit batch-axis splice — the
  bench pins that the unified interface serves both families from the
  same engine, streams identical again.

Reduced-config choices: ``reduced()`` keeps every architectural feature
(GQA ratio, block pattern, norms) at toy width; ``vocab=97`` (prime)
makes stream mismatches loud; ``cache_len=32`` with ``page_size=8`` gives
4 pages per slot — prompts of 6 plus up to 18 new tokens never ring; a
fixed prompt length keeps prefill at one compiled shape.

Rows are schema-1 with kind ``throughput`` (gated higher-is-better, wide
relative band — see ``check_regression.py``): tok/s next to engine steps,
the first step's wall time (where jit compilation lands) excluded from
the rate so the gate tracks steady-state decode, not compiler noise::

    python benchmarks/serve_jax.py --smoke        # writes BENCH_jax.json
    python benchmarks/check_regression.py benchmarks/baseline_jax.json \
        BENCH_jax.json --prefix serve/jax_
"""

from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

CACHE_LEN = 32
PAGE_SIZE = 8
PROMPT_LEN = 6
VOCAB = 97

ARCHS = [("transformer", "yi-6b"), ("rwkv", "rwkv6-3b")]


def _trace(smoke: bool) -> list[tuple]:
    """(prompt, new_tokens, gang) triples — identical for every engine."""
    rng = np.random.default_rng(0)
    n_req, max_new = (10, 6) if smoke else (24, 12)
    gangs = ["g0", "g1"]
    out = []
    for i in range(n_req):
        out.append((rng.integers(1, VOCAB, PROMPT_LEN),
                    int(rng.integers(2, max_new + 1)),
                    gangs[i % 2] if i < n_req - 2 else None))
    return out


def _drive(cfg, params, backend, trace, regen_every: int = 3):
    """Run the trace to completion, timing each engine step.  Returns
    (streams, steps, wall_total, wall_first_step, counters)."""
    from repro.serving import ServingEngine
    eng = ServingEngine(cfg, params, n_slots=8, cache_len=CACHE_LEN,
                        backend=backend)
    for prompt, new, gang in trace:
        eng.submit(prompt, new, gang=gang)
    gangs = sorted({g for _, _, g in trace if g})
    durations = []
    steps = 0
    while not eng._drained() and steps < 3000:
        t0 = time.perf_counter()
        eng.step()
        durations.append(time.perf_counter() - t0)
        steps += 1
        if gangs and steps % regen_every == 0:
            eng.regenerate_gang(gangs[(steps // regen_every) % len(gangs)])
    assert len(eng.completed) == len(trace), (len(eng.completed), len(trace))
    streams = {r.rid: tuple(r.out_tokens) for r in eng.completed}
    return streams, steps, sum(durations), durations[0], eng.counters()


def _row(name: str, streams, steps, total, first, counters) -> tuple:
    toks = sum(len(s) for s in streams.values())
    steady = max(total - first, 1e-9)
    tok_s = toks / steady
    derived = (f"steps={steps} tokens={toks} steady={steady:.2f}s"
               f" first_step={first:.2f}s(compile) kv_parks="
               f"{counters['kv_parks']}")
    c = {k: counters[k] for k in ("kv_parks", "kv_splices", "prefills")}
    c.update(steps=steps, tokens=toks)
    return (name, tok_s, derived, c, "throughput")


def run(smoke: bool = False, use_kernel: bool = False) -> list[tuple]:
    import jax
    from repro.configs import get_config
    from repro.models import api
    from repro.serving import JaxModelBackend, PagedJaxModelBackend

    trace = _trace(smoke)
    rows: list[tuple] = []
    for label, arch in ARCHS:
        cfg = get_config(arch).reduced(vocab=VOCAB)
        params = api.init(cfg, jax.random.PRNGKey(0))

        dense = _drive(cfg, params,
                       JaxModelBackend(cfg, params, CACHE_LEN), trace)
        pb = PagedJaxModelBackend(cfg, params, CACHE_LEN,
                                  page_size=PAGE_SIZE,
                                  use_kernel=use_kernel)
        paged = _drive(cfg, params, pb, trace)

        # the paged layout must be invisible in the output: same trace,
        # token-identical streams
        assert dense[0] == paged[0], \
            f"{arch}: paged backend changed decode output"
        # single host, so every park re-splices into the same shard: a
        # migration is a metadata write, never a pool copy
        assert pb.stats["pool_copies"] == 0, pb.stats
        if label == "transformer":
            assert pb.stats["table_splices"] > 0, \
                "trace exercised no metadata splices"

        rows.append(_row(f"serve/jax_{label}_tok_s", *paged))
        rows[-1][3].update(pb.stats)
        rows.append(_row(f"serve/jax_{label}_dense_tok_s", *dense))
    return rows


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Write serve/jax_* rows into a schema-1 BENCH json (replacing
    previous jax-serve rows, preserving anything else)."""
    doc = {"schema": 1, "suite": "jax-serve", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        doc["rows"] = [r for r in doc["rows"]
                       if not r["name"].startswith("serve/jax_")]
    for name, v, d, counters, kind in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": kind, "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} jax-serve rows into {path}",
          file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_jax.json"
    elif smoke:
        json_path = "BENCH_jax.json"
    rows = run(smoke=smoke, use_kernel="--kernel" in argv)
    for name, v, d, _, _ in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
