"""Elastic-fleet benchmark: live host loss and scale-out under load.

Two gated rows, both replaying the same open-loop trace (Poisson arrivals,
heavy-tailed lengths) against a 2-host fleet:

* ``serve/host_loss_goodput`` — a host dies mid-trace.  The **elastic**
  engine (``kill_host`` + checkpointed ``KVStore``) re-homes the dead
  host's queued work one level up, restores each orphaned resident from
  the newest KV snapshot or re-prefills it (whichever the bill model
  quotes cheaper) and re-deals the survivors; the **baseline** is the
  drain-and-restart operator (``restart=True``): every in-flight request
  fleet-wide is torn down and re-prefilled from scratch, snapshots
  unused.  The row is the goodput ratio — baseline steps over elastic
  steps to drain the identical trace (higher is better, kind
  ``speedup``).  Both runs must lose **zero** requests and produce
  streams token-identical to an undisturbed fleet — elasticity may never
  change what is decoded, only when.

* ``serve/scaleout_speedup`` — a host joins mid-trace under the same
  open-loop load (``join_host``: fresh slots, fresh backend shard, a
  proactive re-spread bought only when the quote beats stealing).  The
  row is steps-to-drain ignoring the new host over steps-to-drain using
  it; the joiner must actually decode (its per-host ledger row is
  asserted non-zero) and streams must match the no-join run exactly.

Standalone entry point merges rows into the serve-gate JSON — run AFTER
``serve_gangs.py`` (whose merge replaces every ``serve/`` row); like
``serve_open_loop.py`` it only replaces its own rows::

    python benchmarks/serve_gangs.py --smoke --json BENCH_serve.json
    python benchmarks/serve_open_loop.py --smoke --json BENCH_serve.json
    python benchmarks/serve_elastic.py --smoke --json BENCH_serve.json
    python benchmarks/check_regression.py benchmarks/baseline_smoke.json \
        BENCH_serve.json --prefix serve/
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.checkpoint import KVStore
from repro.core.bubble import reset_ids
from repro.serving import (SERVE_COST, ServingEngine, StubModelBackend,
                           make_trace)

N_SLOTS = 16          # 2 hosts x 2 KV page groups x 4 slots
TRACE = dict(steps=96, rate=1.5, seed=2)
KILL_AT = 40          # mid-trace, deep in decode: HBM full of restorable KV
JOIN_AT = 24          # early join: most of the trace still benefits
CADENCE = 4


def _engine(**kw) -> ServingEngine:
    reset_ids()
    return ServingEngine(None, None, n_slots=N_SLOTS, group=4, hosts=2,
                         backend=StubModelBackend(), cost_model=SERVE_COST,
                         **kw)


def _streams(eng: ServingEngine) -> dict:
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


def _drive(eng: ServingEngine, trace, *, event_at=None, event=None,
           max_steps: int = 60000):
    """Open-loop drive with one mid-trace fleet event: submit each arrival
    at its step, fire ``event(eng)`` once the clock reaches ``event_at``,
    run to drain."""
    pending = sorted(trace, key=lambda r: r.step)
    i, fired = 0, False
    while i < len(pending) or not eng._drained():
        now = eng.steps
        if event is not None and not fired and now >= event_at:
            event(eng)
            fired = True
        while i < len(pending) and pending[i].step <= now:
            r = pending[i]
            i += 1
            eng.submit(r.prompt, r.new_tokens, sla=r.sla, gang=r.gang)
        eng.step()
        assert eng.steps <= max_steps, "drive did not drain"
    return eng


def host_loss_row(trace, ref_streams: dict) -> tuple:
    with tempfile.TemporaryDirectory() as tmp:
        elastic = _drive(_engine(kv_store=KVStore(tmp, CADENCE)), trace,
                         event_at=KILL_AT,
                         event=lambda e: e.kill_host("host1"))
    base = _drive(_engine(), trace, event_at=KILL_AT,
                  event=lambda e: e.kill_host("host1", restart=True))
    for eng, label in ((elastic, "elastic"), (base, "restart")):
        got = _streams(eng)
        assert len(got) == len(trace), \
            f"{label}: lost requests ({len(got)}/{len(trace)})"
        assert got == ref_streams, f"{label}: streams diverged from " \
            "the undisturbed fleet"
    c = elastic.counters()
    assert c["kv_restores"] >= 1, "snapshot restore path never exercised"
    assert base.counters()["kv_restores"] == 0     # baseline ignores store
    c["restart_steps"] = base.steps
    c["restart_reprefills"] = base.counters()["reprefills"]
    ratio = base.steps / elastic.steps
    return ("serve/host_loss_goodput", ratio,
            f"kill@{KILL_AT}: drain {base.steps}->{elastic.steps} steps, "
            f"{c['orphaned']} orphans ({c['kv_restores']} restored, "
            f"{c['reprefills']} re-prefilled) vs restart "
            f"{c['restart_reprefills']} re-prefills, 0 lost",
            c, "speedup")


def scaleout_row(trace, ref_streams: dict) -> tuple:
    ignore = _drive(_engine(), trace)
    join = _drive(_engine(), trace, event_at=JOIN_AT,
                  event=lambda e: e.join_host())
    for eng, label in ((ignore, "ignore"), (join, "join")):
        got = _streams(eng)
        assert len(got) == len(trace), f"{label}: lost requests"
        assert got == ref_streams, f"{label}: streams diverged"
    c = join.counters()
    assert c["host_joins"] == 1
    assert c["host_decode_steps"][-1] > 0, "the joined host never decoded"
    c["ignore_steps"] = ignore.steps
    ratio = ignore.steps / join.steps
    return ("serve/scaleout_speedup", ratio,
            f"join@{JOIN_AT}: drain {ignore.steps}->{join.steps} steps, "
            f"joiner decoded {c['host_decode_steps'][-1]} steps",
            c, "speedup")


def run(smoke: bool = False) -> list[tuple]:
    trace = make_trace(**TRACE)
    ref = _drive(_engine(), trace)     # the undisturbed fleet: stream oracle
    assert len(ref.completed) == len(trace)
    ref_streams = _streams(ref)
    return [host_loss_row(trace, ref_streams),
            scaleout_row(trace, ref_streams)]


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Replace only this module's rows (``serve_gangs`` owns the wholesale
    ``serve/`` replace; this must run after it)."""
    doc = {"schema": 1, "suite": "smoke", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        mine = {name for name, *_ in rows}
        doc["rows"] = [r for r in doc["rows"] if r["name"] not in mine]
    for name, v, d, counters, kind in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": kind, "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} elastic rows into {path}", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_smoke.json"
    elif smoke:
        json_path = "BENCH_smoke.json"
    rows = run(smoke=smoke)
    for name, v, d, _, kind in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
