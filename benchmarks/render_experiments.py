"""Render the data-driven sections of EXPERIMENTS.md from result JSONs.

Usage: PYTHONPATH=src:. python -m benchmarks.render_experiments > /tmp/tables.md

The steal/rebalance section consumes the ``BENCH_smoke.json`` written by
``benchmarks/run.py --smoke`` (falling back to the committed
``baseline_smoke.json``), rendering the per-level steal histograms that
:meth:`repro.core.trace.Tracer.steals_by_level` collects and the
``SimResult.extra`` steal/rebalance counters — steal behaviour plotted per
level, not just counted.
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

R = Path(__file__).resolve().parent / "results"
ROOT = Path(__file__).resolve().parent.parent


def fmt_bytes(b):
    if b is None:
        return "?"
    return f"{b/2**30:.2f}"


def roofline_table(mesh_filter: str) -> str:
    rows = []
    for f in sorted(glob.glob(str(R / "dryrun" / "*.json"))):
        d = json.load(open(f))
        pods = "2pod" if "pod" in d["mesh"] else "1pod"
        if pods != mesh_filter or d["strategy"] != "bubbles":
            continue
        r = d["roofline"]
        m = d["memory"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | "
            f"{r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} | "
            f"{r['t_collective_s']*1e3:.1f} | **{r['bottleneck'][:4]}** | "
            f"{r['model_flops']:.2e} | {r['useful_fraction']:.2f} | "
            f"{r['mfu_at_roofline']*100:.1f}% | "
            f"{fmt_bytes(m['argument_bytes_per_chip'])} | "
            f"{'Y' if m['fits'] else 'N'} |")
    head = ("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bneck | "
            "MODEL_FLOPS | useful | MFU@roof | args GiB/chip | fits |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def collective_summary(mesh_filter: str) -> str:
    rows = []
    for f in sorted(glob.glob(str(R / "dryrun" / "*.json"))):
        d = json.load(open(f))
        pods = "2pod" if "pod" in d["mesh"] else "1pod"
        if pods != mesh_filter or d["strategy"] != "bubbles":
            continue
        c = d["collectives"]
        parts = [f"{k}:{v['count']}x/{v['bytes']/2**30:.1f}GiB"
                 for k, v in c.items()]
        rows.append(f"| {d['arch']} | {d['shape']} | {' '.join(parts)} |")
    return ("| arch | shape | collective schedule (per-chip bytes, depth-2 "
            "unrolled probe) |\n|---|---|---|\n" + "\n".join(rows))


def perf_iteration_table() -> str:
    rows = []
    for f in sorted(glob.glob(str(R / "perf_iterations" / "*.json"))):
        d = json.load(open(f))
        name = Path(f).stem
        r = d["roofline"]
        m = d.get("memory", {})
        mesh = d.get("mesh", {})
        rows.append(
            f"| {name} | {d.get('arch','?')} {d.get('shape','')} | "
            f"{d.get('strategy','?')} {tuple(mesh.values())} | "
            f"{r['t_step_s']*1e3:.0f} | {r['bottleneck'][:4]} | "
            f"{r['useful_fraction']:.2f} | {r['mfu_at_roofline']*100:.2f}% | "
            f"{'Y' if m.get('fits') else 'N'} |")
    return ("| iteration | cell | strategy/mesh | t_step ms | bneck | useful "
            "| MFU@roof | fits |\n|---|---|---|---|---|---|---|---|\n"
            + "\n".join(rows))


def _bench_rows() -> list[dict]:
    for cand in (ROOT / "BENCH_smoke.json",
                 Path.cwd() / "BENCH_smoke.json",
                 ROOT / "benchmarks" / "baseline_smoke.json"):
        if cand.exists():
            return json.load(open(cand))["rows"]
    return []


def steal_level_table() -> str:
    """Per-policy steal/rebalance behaviour, steals split by victim level.

    One row per Table 2 stealing run; the ``steals by level`` column is a
    tiny inline bar chart per hierarchy level (one ``#`` per 8 steals), so
    the affinity invariant — steals should concentrate on local levels,
    and the adaptive policy should replace steal traffic with a handful of
    rebalances — is visible at a glance."""
    rows = []
    for r in _bench_rows():
        c = r.get("counters")
        if c is None or "steals_by_level" not in c:
            continue
        by_level = c["steals_by_level"]
        levels = " ".join(
            f"{lvl}:{n}{'#' * max(1, n // 8)}"
            for lvl, n in sorted(by_level.items())) or "-"
        rows.append(
            f"| {r['name'].split('/')[-1]} | {r['value']:.2f} | "
            f"{c['steals']} | {levels} | {c['rebalances']} "
            f"({c['rebalance_moves']} moves) | "
            f"{c['steal_cost'] + c['rebalance_cost']:.0f} | "
            f"{c['data_migrations']} |")
    if not rows:
        return ("_no BENCH_smoke.json found — run `make bench-smoke` to "
                "generate the steal/rebalance section_")
    head = ("| run | speedup | steals | steals by level | rebalances | "
            "migration cost paid | data migr |\n"
            "|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


if __name__ == "__main__":
    print("## steal/rebalance behaviour per level (Table 2 runs)\n")
    print(steal_level_table())
    print("\n## 1-pod roofline (bubbles strategy)\n")
    print(roofline_table("1pod"))
    print("\n## 2-pod roofline (bubbles strategy)\n")
    print(roofline_table("2pod"))
    print("\n## collectives (1pod)\n")
    print(collective_summary("1pod"))
    print("\n## perf iterations\n")
    print(perf_iteration_table())
