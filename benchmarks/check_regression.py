"""Benchmark-regression gate: compare a BENCH json against the baseline.

Usage::

    python benchmarks/check_regression.py BASELINE.json CURRENT.json \
        [--tolerance 0.10] [--prefix table2/]

``--prefix`` restricts the gate to rows whose name starts with the given
prefix — for partial runs (e.g. ``serve_gangs.py --smoke`` writes only
``serve/`` rows; gating the full baseline against it would flag every
other row as missing).

Gates on ``kind == "speedup"`` rows (Table 2 + serving): the current speedup must be
at least ``baseline * (1 - tolerance)``.  Gain-% and wall-clock rows are
reported but not gated — speedups are the paper's headline metric and are
fully deterministic in the simulator, so a >10% drop is a real scheduling
regression, not noise.  A gated baseline row that disappears from the
current run also fails (a silently dropped benchmark is a regression in
coverage).  New rows are allowed — commit a refreshed baseline to start
gating them.

Exit codes: 0 ok, 1 regression(s), 2 usage/IO error.  To refresh the
baseline after an intentional change::

    make bench-smoke && cp BENCH_smoke.json benchmarks/baseline_smoke.json
"""

from __future__ import annotations

import json
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    assert doc.get("schema") == 1, f"{path}: unknown schema {doc.get('schema')}"
    return {r["name"]: r for r in doc["rows"]}


def main(argv: list[str]) -> int:
    tolerance = 0.10
    prefix = ""
    args = []
    i = 0
    while i < len(argv):
        if argv[i] == "--tolerance":
            if i + 1 >= len(argv):
                print("error: --tolerance needs a value")
                return 2
            try:
                tolerance = float(argv[i + 1])
            except ValueError:
                print(f"error: --tolerance needs a number, got {argv[i + 1]!r}")
                return 2
            i += 2
            continue
        if argv[i] == "--prefix":
            if i + 1 >= len(argv):
                print("error: --prefix needs a value")
                return 2
            prefix = argv[i + 1]
            i += 2
            continue
        if argv[i].startswith("--"):
            print(f"error: unknown flag {argv[i]}")
            return 2
        args.append(argv[i])
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 2
    try:
        base = load_rows(args[0])
        cur = load_rows(args[1])
    except (OSError, json.JSONDecodeError, AssertionError) as e:
        print(f"error: {e}")
        return 2

    failures, checked = [], 0
    for name, brow in sorted(base.items()):
        if brow.get("kind") != "speedup" or not name.startswith(prefix):
            continue
        crow = cur.get(name)
        if crow is None:
            failures.append(f"{name}: gated row missing from current run "
                            f"(baseline {brow['value']:.4f})")
            continue
        checked += 1
        floor = brow["value"] * (1.0 - tolerance)
        status = "FAIL" if crow["value"] < floor else "ok"
        print(f"{status:4s} {name:40s} base={brow['value']:8.4f} "
              f"cur={crow['value']:8.4f} floor={floor:8.4f}")
        if crow["value"] < floor:
            failures.append(
                f"{name}: {crow['value']:.4f} < floor {floor:.4f} "
                f"({(1 - crow['value'] / brow['value']) * 100:.1f}% below "
                f"baseline {brow['value']:.4f})")
    for name in sorted(set(cur) - set(base)):
        if cur[name].get("kind") == "speedup" and name.startswith(prefix):
            print(f"new  {name:40s} cur={cur[name]['value']:8.4f} "
                  "(ungated; refresh baseline to gate)")

    print(f"\n{checked} speedup rows checked against tolerance "
          f"{tolerance:.0%}; {len(failures)} regression(s)")
    for f in failures:
        print(f"REGRESSION: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
