"""Benchmark-regression gate: compare a BENCH json against the baseline.

Usage::

    python benchmarks/check_regression.py BASELINE.json CURRENT.json \
        [--tolerance 0.10] [--gain-tolerance 5.0] [--latency-tolerance 3.0] \
        [--throughput-tolerance 0.70] [--prefix table2/]

``--prefix`` restricts the gate to rows whose name starts with the given
prefix — for partial runs (e.g. ``serve_gangs.py --smoke`` writes only
``serve/`` rows; gating the full baseline against it would flag every
other row as missing).  A prefix that matches **zero** gated baseline rows
is a usage error (exit 2): a typo'd prefix must not silently gate nothing
and pass.

Four kinds of row are gated:

* ``kind == "speedup"`` (Table 2 + serving): the current speedup must be
  at least ``baseline * (1 - tolerance)`` — a *relative* band, because a
  15x conduction speedup and a 1.3x serving speedup tolerate
  proportionally similar jitter.
* ``kind == "gain_pct"`` (Fig 5): the current gain must be at least
  ``baseline - gain_tolerance`` — an *absolute* band in percentage
  points.  Gains are already ratios of two runtimes expressed in percent;
  a relative band would be meaninglessly tight near 0% and uselessly
  loose near 60%, so the band is points (default 5.0 — generous for a
  fully deterministic simulator, tight enough that a real placement
  regression, which historically costs 10+ points, still fails).
* ``kind == "latency"`` (the open-loop p99-TTFT rows): **lower is
  better** — the current value must be at most ``baseline +
  latency_tolerance``, an absolute band in the row's own units (engine
  steps; same spirit as the gain band: percentile latencies near zero
  would make any relative band meaningless).
* ``kind == "throughput"`` (the jax-serve tok/s rows): higher is better,
  relative floor ``baseline * (1 - throughput_tolerance)`` — but with a
  deliberately *wide* default band (0.70: the gate trips below 30% of
  baseline).  Unlike every other gated kind these rows are **wall-clock**
  measurements of real jitted model steps on shared CI runners, where
  2-3x machine-to-machine variance is normal and not a regression.  The
  failure mode worth gating is categorical collapse — a per-step
  recompile (stable jit signatures broken), a Python-loop fallback, an
  accidental O(n^2) splice — which costs 10x+, far outside any runner
  noise.  A tight band here would only train people to ignore the lane.

Wall-clock rows (``us_per_call``, ``step_ms``) are reported but not gated
— they are the only nondeterministic rows.  A gated baseline row that
disappears from the current run also fails (a silently dropped benchmark
is a regression in coverage).  New rows are allowed — commit a refreshed
baseline to start gating them.

Every gated row's report line carries its delta vs baseline (absolute and
percent), so the perf trajectory is readable straight from the CI job log
without diffing artifacts, and the same per-row deltas are written back
into the *current* ``BENCH_*.json`` under a top-level ``"deltas"`` key —
the artifact a CI run uploads then records not just what it measured but
how far it moved.  The write is best-effort: a read-only artifact degrades
to log-only, never to a gate failure.

Exit codes: 0 ok, 1 regression(s), 2 usage/IO error.  To refresh the
baseline after an intentional change::

    make bench-smoke && cp BENCH_smoke.json benchmarks/baseline_smoke.json
"""

from __future__ import annotations

import json
import sys

GATED_KINDS = ("speedup", "gain_pct", "latency", "throughput")


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    assert doc.get("schema") == 1, f"{path}: unknown schema {doc.get('schema')}"
    return {r["name"]: r for r in doc["rows"]}


def bound_for(row: dict, tolerance: float, gain_tolerance: float,
              latency_tolerance: float,
              throughput_tolerance: float) -> tuple[float, bool]:
    """The gate bound and its direction as ``(bound, lower_is_better)``:
    a relative floor for speedups, an absolute-points floor for gain
    percentages, an absolute-band *ceiling* for latency rows, and a wide
    relative floor for wall-clock throughput rows (see the module
    docstring for the rationale)."""
    if row.get("kind") == "latency":
        return row["value"] + latency_tolerance, True
    if row.get("kind") == "gain_pct":
        return row["value"] - gain_tolerance, False
    if row.get("kind") == "throughput":
        return row["value"] * (1.0 - throughput_tolerance), False
    return row["value"] * (1.0 - tolerance), False


def main(argv: list[str]) -> int:
    tolerance = 0.10
    gain_tolerance = 5.0
    latency_tolerance = 3.0
    throughput_tolerance = 0.70
    prefix = ""
    args = []
    i = 0
    while i < len(argv):
        if argv[i] in ("--tolerance", "--gain-tolerance",
                       "--latency-tolerance", "--throughput-tolerance"):
            flag = argv[i]
            if i + 1 >= len(argv):
                print(f"error: {flag} needs a value")
                return 2
            try:
                value = float(argv[i + 1])
            except ValueError:
                print(f"error: {flag} needs a number, got {argv[i + 1]!r}")
                return 2
            if flag == "--tolerance":
                tolerance = value
            elif flag == "--gain-tolerance":
                gain_tolerance = value
            elif flag == "--latency-tolerance":
                latency_tolerance = value
            else:
                throughput_tolerance = value
            i += 2
            continue
        if argv[i] == "--prefix":
            if i + 1 >= len(argv):
                print("error: --prefix needs a value")
                return 2
            prefix = argv[i + 1]
            i += 2
            continue
        if argv[i].startswith("--"):
            print(f"error: unknown flag {argv[i]}")
            return 2
        args.append(argv[i])
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 2
    try:
        base = load_rows(args[0])
        cur = load_rows(args[1])
    except (OSError, json.JSONDecodeError, AssertionError) as e:
        print(f"error: {e}")
        return 2

    gated = sorted(name for name, row in base.items()
                   if row.get("kind") in GATED_KINDS
                   and name.startswith(prefix))
    if not gated:
        # a typo'd prefix would otherwise gate nothing and exit 0 — the
        # most dangerous way for a CI gate to "pass".  Distinguish the
        # no-prefix case so an operator is not sent hunting a flag they
        # never passed.
        if prefix:
            print(f"error: --prefix {prefix!r} matched no gated baseline "
                  f"rows in {args[0]} ({len(base)} rows total)")
        else:
            print(f"error: {args[0]} contains no gated rows "
                  f"(kinds {GATED_KINDS}; {len(base)} rows total)")
        return 2

    failures = []
    deltas = {}
    for name in gated:
        brow = base[name]
        crow = cur.get(name)
        if crow is None:
            failures.append(f"{name}: gated row missing from current run "
                            f"(baseline {brow['value']:.4f})")
            continue
        bound, lower_better = bound_for(brow, tolerance, gain_tolerance,
                                        latency_tolerance,
                                        throughput_tolerance)
        if lower_better:
            bad = crow["value"] > bound
            word, cmp = "ceil", ">"
        else:
            bad = crow["value"] < bound
            word, cmp = "floor", "<"
        status = "FAIL" if bad else "ok"
        delta = crow["value"] - brow["value"]
        pct = 100.0 * delta / brow["value"] if brow["value"] else 0.0
        deltas[name] = {"kind": brow.get("kind"), "base": brow["value"],
                        "cur": crow["value"], "delta": round(delta, 6),
                        "delta_pct": round(pct, 2), "status": status}
        print(f"{status:4s} {name:40s} base={brow['value']:8.4f} "
              f"cur={crow['value']:8.4f} d={delta:+8.4f} ({pct:+6.1f}%) "
              f"{word}={bound:8.4f}")
        if bad:
            band = "rel" if brow.get("kind") in ("speedup", "throughput") \
                else "abs"
            failures.append(
                f"{name}: {crow['value']:.4f} {cmp} {word} {bound:.4f} "
                f"(baseline {brow['value']:.4f}, {band} band)")
    for name in sorted(set(cur) - set(base)):
        if cur[name].get("kind") in GATED_KINDS and name.startswith(prefix):
            print(f"new  {name:40s} cur={cur[name]['value']:8.4f} "
                  "(ungated; refresh baseline to gate)")

    if deltas:
        # stamp the per-row deltas into the current artifact so a CI run's
        # uploaded BENCH_*.json records its movement vs baseline, not just
        # its raw values.  Best-effort: a read-only artifact is a logging
        # loss, not a gate failure.
        try:
            with open(args[1]) as f:
                doc = json.load(f)
            doc["deltas"] = {"baseline": args[0], "rows": deltas}
            with open(args[1], "w") as f:
                json.dump(doc, f, indent=1)
                f.write("\n")
        except OSError as e:
            print(f"note: could not write deltas into {args[1]}: {e}")

    print(f"\n{len(gated)} gated rows checked (speedup band {tolerance:.0%}, "
          f"gain band {gain_tolerance:g} points, "
          f"latency band {latency_tolerance:g} steps, "
          f"throughput band {throughput_tolerance:.0%}); "
          f"{len(failures)} regression(s)")
    for f in failures:
        print(f"REGRESSION: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
