"""Paper Table 1: scheduler-step cost (Yield = list search, Switch = swap).

Measures the wall-clock cost of one scheduler decision for the flat
single-list scheduler vs the hierarchical bubble scheduler, mirroring the
paper's Marcel-original (186ns yield) vs Marcel-bubbles (250ns) comparison:
the hierarchy costs a constant factor (linear in the number of levels,
paper §4) and stays far below a kernel-level scheduler (NPTL: 672ns).

Output CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

from repro.core import (BubbleScheduler, SimplePolicy, balanced_tree,
                        novascale_16, numa_4x4_smt, thread)


def _bench(fn, n: int = 2000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_flat_yield() -> float:
    """Flat single-list yield: full max-priority scan (what a Linux-2.4
    style goodness() pass does over the global runqueue of 64 threads)."""
    queue = [thread(1.0, prio=i % 3) for i in range(64)]

    def one():
        best = max(range(len(queue)), key=lambda i: queue[i].prio)
        t = queue.pop(best)
        queue.append(t)

    return _bench(one)


def bench_bubble_yield(topo_fn=novascale_16) -> float:
    """Hierarchical yield at steady state: the same 64 threads distributed
    over the per-cpu lists (4 per leaf on the NovaScale), two-pass lookup
    over the covering chain."""
    topo = topo_fn()
    sched = BubbleScheduler(topo)
    per = 64 // topo.n_cpus
    for cpu in range(topo.n_cpus):
        q = sched.queues.covering(cpu)[0]
        for i in range(per):
            q.push(thread(1.0, prio=i % 3))

    def one():
        t = sched.next_thread(0, allow_steal=False)
        if t is not None:
            sched.queues.covering(0)[0].push(t)

    return _bench(one)


def bench_levels_scaling() -> tuple[float, float]:
    """Lookup cost must be linear in the number of levels (paper §4)."""
    a = bench_bubble_yield(novascale_16)      # 3 levels
    b = bench_bubble_yield(numa_4x4_smt)      # 5 levels
    return a, b


def run() -> list[tuple[str, float, str]]:
    rows = []
    flat = bench_flat_yield()
    bub3 = bench_bubble_yield(novascale_16)
    bub5 = bench_bubble_yield(numa_4x4_smt)
    rows.append(("table1/flat_yield", flat, "paper Marcel original: 0.186us"))
    rows.append(("table1/bubble_yield_3lvl", bub3,
                 "paper Marcel bubbles: 0.250us"))
    rows.append(("table1/bubble_yield_5lvl", bub5,
                 f"levels scaling x{bub5/max(bub3,1e-9):.2f} (linear in depth)"))
    rows.append(("table1/overhead_ratio", bub3 / max(flat, 1e-9),
                 "paper ratio: 250/186 = 1.34"))
    return rows


if __name__ == "__main__":
    for name, v, d in run():
        print(f"{name},{v:.3f},{d}")
