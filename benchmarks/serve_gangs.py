"""Serving-engine gang benchmark: the runtime layer vs plain admission.

Two sections, both on the deterministic stub model (``StubModelBackend``:
no jit compile — the scheduler stack is the system under test, the model is
a hash chain whose output detects any KV mishandling):

* **skewed** — one fat shared-prefix gang plus a handful of small gangs and
  lone requests with mixed SLA priorities.  The fat gang bursts onto one KV
  page group and floods it; plain admission leaves the other page group's
  slots idle once their small gangs finish.  The runtime-backed engine
  (steal-driven admission + next-touch KV re-homing + queue-depth
  rebalance) must complete the same request set in measurably fewer engine
  steps — ``serve/skewed_steal_speedup`` is the gated row (acceptance:
  >= 1.2x).
* **churn** — many tiny gangs with periodic gang regeneration
  (client backpressure), exercising the KV park / batched-splice path under
  migration: every interrupted request resumes its exact continuation
  (asserted), and the counters prove steals, KV migrations, and rebalances
  actually fired.

Rows are schema-1 (see ``benchmarks/run.py``) with a ``counters`` dict; the
standalone entry point merges them into ``BENCH_smoke.json`` so the
``check_regression.py`` gate covers serving throughput too::

    python benchmarks/serve_gangs.py --smoke            # writes/merges JSON
    python benchmarks/check_regression.py benchmarks/baseline_smoke.json \
        BENCH_smoke.json --prefix serve/
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from repro.serving import ServingEngine, StubModelBackend

N_SLOTS = 8          # 2 KV page groups x 4 slots
NEW_TOKENS = 12

# (gang, n_requests, prio): one fat gang, small gangs, lone requests.  The
# fat gang is wider than a page group's slot count, so its backlog pins one
# page while the other drains — only steal/rebalance keep both busy.
SKEWED = [("fat", 16, 0), ("a", 2, 2), ("b", 1, 1), (None, 2, 1)]

CHURN = [(f"g{i}", 2, i % 3) for i in range(8)]       # 16 requests, 8 gangs


def _submit(eng: ServingEngine, spec) -> int:
    rng = np.random.default_rng(0)
    n = 0
    for gang, count, prio in spec:
        for _ in range(count):
            eng.submit(rng.integers(1, 250, 8), NEW_TOKENS,
                       prio=prio, gang=gang)
            n += 1
    return n


def _engine(mode: str) -> ServingEngine:
    return ServingEngine(None, None, n_slots=N_SLOTS,
                         backend=StubModelBackend(), mode=mode)


def _run(mode: str, spec, regen_every: int = 0) -> ServingEngine:
    eng = _engine(mode)
    n = _submit(eng, spec)
    gangs = [g for g, _, _ in spec if g is not None]
    steps = 0
    while not eng._drained() and steps < 5000:
        eng.step()
        steps += 1
        if regen_every and steps % regen_every == 0:
            # rolling backpressure: park whichever of these gangs is in
            # the slots right now (deterministic round-robin)
            eng.regenerate_gang(gangs[(steps // regen_every) % len(gangs)])
    assert len(eng.completed) == n, (mode, len(eng.completed), n)
    return eng


def _streams(eng: ServingEngine) -> dict:
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


def run(smoke: bool = False) -> list[tuple]:
    rows: list[tuple] = []

    # -- skewed gangs: the steal/rebalance win -------------------------------
    base = _run("admission", SKEWED)
    fast = _run("runtime", SKEWED)
    # scheduling must never change results: same streams in both modes
    assert _streams(base) == _streams(fast), "mode changed decode output"
    speedup = base.steps / fast.steps
    c = fast.counters()
    c["steps_admission"] = base.steps
    rows.append((
        "serve/skewed_steal_speedup", speedup,
        f"steps {base.steps}->{fast.steps} steals={c['steals']}"
        f" rebalances={c['rebalances']} kv_migrations={c['kv_migrations']}",
        c))

    # -- gang churn: regeneration + KV park/splice under migration -----------
    base = _run("admission", CHURN, regen_every=4)
    fast = _run("runtime", CHURN, regen_every=4)
    uninterrupted = _run("runtime", CHURN)
    assert _streams(fast) == _streams(uninterrupted), \
        "regeneration/migration changed decode output"
    c = fast.counters()
    c["steps_admission"] = base.steps
    rows.append((
        "serve/churn_regen_speedup", base.steps / fast.steps,
        f"steps {base.steps}->{fast.steps} kv_parks={c['kv_parks']}"
        f" kv_splices={c['kv_splices']} data_migrations="
        f"{c['data_migrations']}",
        c))
    return rows


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Merge serve/* rows into a schema-1 BENCH json (replacing previous
    serve rows, preserving everything else)."""
    doc = {"schema": 1, "suite": "smoke", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        doc["rows"] = [r for r in doc["rows"]
                       if not r["name"].startswith("serve/")]
    for name, v, d, counters in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": "speedup", "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} serve rows into {path}", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_smoke.json"
    elif smoke:
        json_path = "BENCH_smoke.json"
    rows = run(smoke=smoke)
    for name, v, d, _ in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
