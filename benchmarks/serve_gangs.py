"""Serving-engine gang benchmark: the runtime layer vs plain admission.

Two sections, both on the deterministic stub model (``StubModelBackend``:
no jit compile — the scheduler stack is the system under test, the model is
a hash chain whose output detects any KV mishandling):

* **skewed** — one fat shared-prefix gang plus a handful of small gangs and
  lone requests with mixed SLA priorities.  The fat gang bursts onto one KV
  page group and floods it; plain admission leaves the other page group's
  slots idle once their small gangs finish.  The runtime-backed engine
  (steal-driven admission + next-touch KV re-homing + queue-depth
  rebalance) must complete the same request set in measurably fewer engine
  steps — ``serve/skewed_steal_speedup`` is the gated row (acceptance:
  >= 1.2x).
* **churn** — many tiny gangs with periodic gang regeneration
  (client backpressure), exercising the KV park / batched-splice path under
  migration: every interrupted request resumes its exact continuation
  (asserted), and the counters prove steals, KV migrations, and rebalances
  actually fired.
* **multihost** — the pod-sharded fleet (2 pods x 2 hosts x 2 KV page
  groups x 4 slots): a fat gang floods host0 while every other host holds
  local backlog reachable only through the steal survey (homed on one of
  its two page lists).  The DCN-naive engine ranks victims with flat
  per-level costs (``FLAT_SERVE_COST``) but pays real DCN latency
  (``bill_model=SERVE_COST``), so it keeps dragging heavy remote loot
  across the pod boundary while its own backlog waits; the DCN-priced
  engine steals its cheap sibling-page work first.
  ``serve/multihost_steal_speedup`` is the gated row (acceptance: >= 1.2x,
  identical decode streams asserted).
* **hbm pressure** — per-page-group HBM budgets tighter than the slot
  count: the capacity-aware engine refuses loot that will not fit (the
  steal survey skips full groups, admission parks gangs), the
  capacity-blind baseline claims first and discovers fullness at splice
  time — paying steal bills for loot that bounces straight back.
  ``serve/hbm_pressure_refusal_speedup`` is the gated row.
* **dcn rebalance** — the skewed-pod fleet again, but admission-bound:
  every host's own backlog is homed on ONE of its two page lists (real
  within-host skew on every host) and the small requests are short, so
  throughput lives or dies on admission latency.  Both engines price
  steals with the DCN table; they differ only in the rebalance mode.
  The ``dcn_rebalance`` engine quotes each prospective re-spread through
  ``BubbleScheduler.estimate_rebalance`` (every move priced by the
  boundary it crosses) and buys host-local page shuffles; the baseline
  keeps the historical flat-quoted machine-wide re-spread — whose moves
  now bill their true level-table tolls, landing as admission freezes on
  every page group that receives cross-host loot.
  ``serve/dcn_rebalance_speedup`` is the gated row (acceptance: >= 1.2x,
  identical decode streams asserted).
* **bandwidth pricing** — the physical cost model's per-byte term: steal
  and rebalance bills scale with the KV bytes a move drags
  (``BW_SERVE_COST``'s level-table triples; cheap within the pod,
  DCN-priced across it).  The byte-naive engine believes flat boundary
  tolls (``SERVE_COST``) but pays ``BW_SERVE_COST``, so its thief host
  keeps dragging heavy remote KV across the pod — freezing its slots in
  transfer stalls while its same-pod neighbour's backlog waits; the
  byte-priced engine rescues the cheap same-pod work and leaves heavy KV
  where its own pod drains it.  ``serve/bandwidth_priced_speedup`` is the
  gated row (acceptance: >= 1.2x, identical decode streams asserted).
* **straggler drain** — one host runs at 0.2x (its decode_step spans five
  engine steps).  Both engines run the same slow machine; only the
  speed-aware one lets the scheduler SEE the skew: the steal survey
  weighs victim backlog by host speed (rescuing the straggler's queue
  first) and refuses to drag work from a faster host onto a slower one
  (no tar-pitting), and the LPT rebalance deal divides loads by speed.
  The lockstep-assuming baseline shuffles heavy fast-host loot while the
  straggler's backlog rots.  ``serve/straggler_drain_speedup`` is the
  gated row (acceptance: >= 1.2x, identical decode streams asserted).
* **gang split** — a gang wider than its home page group's HBM budget is
  stuck: the full group's slots skip admission and every other group's
  survey refuses the whole gang.  The splitting engine quotes spreading
  the members across the host's sibling page groups against parking
  until the residents drain, and buys the cheaper; the park-only
  baseline waits out the residents.  ``serve/gang_split_admission_speedup``
  is the gated row (acceptance: >= 1.2x, identical decode streams
  asserted).

Rows are schema-1 (see ``benchmarks/run.py``) with a ``counters`` dict; the
standalone entry point merges them into ``BENCH_smoke.json`` so the
``check_regression.py`` gate covers serving throughput too::

    python benchmarks/serve_gangs.py --smoke            # writes/merges JSON
    python benchmarks/check_regression.py benchmarks/baseline_smoke.json \
        BENCH_smoke.json --prefix serve/
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from repro.serving import (BW_SERVE_COST, FLAT_SERVE_COST, SERVE_COST,
                           ServingEngine, StubModelBackend)

N_SLOTS = 8          # 2 KV page groups x 4 slots
NEW_TOKENS = 12

# Execution-model knobs threaded into every engine this benchmark builds:
# ``--no-per-host-decode`` falls back to one global decode batch and
# ``--no-wave-prefill`` to the per-request prefill loop.  Neither changes
# a stream or a step count (slots are independent; the engines assert it),
# so the gated rows are knob-invariant — the flags exist to A/B the
# execution model itself (e.g. counter deltas: prefill_waves vs prefills,
# per-host decode ledgers).
ENGINE_KW: dict = {}

# (gang, n_requests, prio): one fat gang, small gangs, lone requests.  The
# fat gang is wider than a page group's slot count, so its backlog pins one
# page while the other drains — only steal/rebalance keep both busy.
SKEWED = [("fat", 16, 0), ("a", 2, 2), ("b", 1, 1), (None, 2, 1)]

CHURN = [(f"g{i}", 2, i % 3) for i in range(8)]       # 16 requests, 8 gangs


def _submit(eng: ServingEngine, spec) -> int:
    rng = np.random.default_rng(0)
    n = 0
    for gang, count, prio in spec:
        for _ in range(count):
            eng.submit(rng.integers(1, 250, 8), NEW_TOKENS,
                       prio=prio, gang=gang)
            n += 1
    return n


def _engine(mode: str) -> ServingEngine:
    return ServingEngine(None, None, n_slots=N_SLOTS,
                         backend=StubModelBackend(), mode=mode,
                         **ENGINE_KW)


def _run(mode: str, spec, regen_every: int = 0) -> ServingEngine:
    eng = _engine(mode)
    n = _submit(eng, spec)
    gangs = [g for g, _, _ in spec if g is not None]
    steps = 0
    while not eng._drained() and steps < 5000:
        eng.step()
        steps += 1
        if regen_every and steps % regen_every == 0:
            # rolling backpressure: park whichever of these gangs is in
            # the slots right now (deterministic round-robin)
            eng.regenerate_gang(gangs[(steps // regen_every) % len(gangs)])
    assert len(eng.completed) == n, (mode, len(eng.completed), n)
    return eng


def _streams(eng: ServingEngine) -> dict:
    return {r.rid: tuple(r.out_tokens) for r in eng.completed}


# -- multi-host: the skewed-pod fleet ---------------------------------------

def _multihost_engine(dcn_aware: bool, **kw) -> ServingEngine:
    """2 pods x 2 hosts x 8 slots; the DCN-naive engine *ranks* steal
    victims with flat per-level prices but *pays* the DCN table — and it
    does not know hosts exist, so its rebalancing is the flat-quoted
    machine-wide mode too (``dcn_rebalance=False``)."""
    if dcn_aware:
        cost, bill, dcn_reb = SERVE_COST, None, True
    else:
        cost, bill, dcn_reb = FLAT_SERVE_COST, SERVE_COST, False
    return ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                         backend=StubModelBackend(), mode="runtime",
                         cost_model=cost, bill_model=bill,
                         dcn_rebalance=dcn_reb, **{**ENGINE_KW, **kw})


def _submit_skewed_pod(eng: ServingEngine) -> int:
    """One fat gang floods host0; every other host gets local backlog homed
    on ONE of its two page lists — reachable by the host's other page only
    through the steal survey, where the fat gang's heavier threads tempt a
    flat-cost ranking into paying DCN drags it did not need."""
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(16):
        eng.submit(rng.integers(1, 250, 8), 28, gang="fat", home="host0")
        n += 1
    for h in range(1, 4):
        for g in range(2):
            for _ in range(8):
                eng.submit(rng.integers(1, 250, 8), 12, gang=f"h{h}g{g}",
                           home=f"page{2 * h}")
                n += 1
    return n


def _run_multihost(dcn_aware: bool) -> ServingEngine:
    eng = _multihost_engine(dcn_aware)
    n = _submit_skewed_pod(eng)
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (dcn_aware, len(eng.completed), n)
    return eng


# -- DCN-priced rebalancing: host-local vs flat machine-wide re-spreads -----

def _submit_dcn_rebalance(eng: ServingEngine) -> int:
    """Admission-bound within-host skew on every host: a fat gang floods
    host0 and each host's own gangs are homed on its FIRST page list only,
    so every host has a local fix available.  The machine-wide re-spread
    scatters the lot across hosts — billing per-move DCN tolls that land
    as admission freezes on the receiving page groups — where the
    host-local mode buys four toll-free page shuffles."""
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(12):
        eng.submit(rng.integers(1, 250, 8), 24, gang="fat", home="host0")
        n += 1
    for h in range(4):
        for g in range(2):
            for _ in range(8):
                eng.submit(rng.integers(1, 250, 8), 4, gang=f"h{h}g{g}",
                           home=f"page{2 * h}")
                n += 1
    return n


def _run_dcn_rebalance(local: bool) -> ServingEngine:
    eng = ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                        backend=StubModelBackend(), mode="runtime",
                        cost_model=SERVE_COST, dcn_rebalance=local,
                        **ENGINE_KW)
    n = _submit_dcn_rebalance(eng)
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (local, len(eng.completed), n)
    return eng


# -- bandwidth pricing: per-byte transfer tolls on the steal survey ---------

def _run_bandwidth(bw_aware: bool) -> ServingEngine:
    """2 pods x 2 hosts x 8 slots, fat KV (8 bytes/request): host0 holds a
    deep backlog of short requests, host1 (same pod) is the idle thief,
    pod 1's hosts churn their own heavy backlog.  The byte-naive survey
    believes flat boundary tolls, so pod 1's heavier loot wins its
    work-per-cost ranking — every drag then bills the true per-byte DCN
    toll (``bill_model=BW_SERVE_COST``), freezing the thief while host0's
    backlog waits.  The byte-priced survey sees the same drag cost what
    it costs and rescues the cheap same-pod work instead."""
    cost = BW_SERVE_COST if bw_aware else SERVE_COST
    bill = None if bw_aware else BW_SERVE_COST
    eng = ServingEngine(None, None, n_slots=32, pods=2, hosts=2,
                        backend=StubModelBackend(), mode="runtime",
                        cost_model=cost, bill_model=bill, kv_bytes=8.0,
                        **ENGINE_KW)
    rng = np.random.default_rng(0)
    n = 0
    for i in range(72):          # host0: deep backlog of short requests
        eng.submit(rng.integers(1, 250, 8), 12, home=f"page{i % 2}")
        n += 1
    # host1 (pod 0): no local work — the thief whose survey is under test
    for h in (2, 3):             # pod 1: heavy, self-draining backlog
        for i in range(16):
            eng.submit(rng.integers(1, 250, 8), 36,
                       home=f"page{2 * h + i % 2}")
            n += 1
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (bw_aware, len(eng.completed), n)
    return eng


# -- straggler drain: one slow host, speed-aware vs lockstep-assuming -------

def _run_straggler(speed_aware: bool) -> ServingEngine:
    """4 hosts x 4 slots, host0 at 0.2x speed with a deep backlog of short
    requests, hosts 1-2 with their own heavy backlog, host3 idle.  Both
    engines run the same slow machine; the speed-aware survey rescues the
    straggler's queue (work / victim speed) and never drags heavy
    fast-host loot onto the straggler, the lockstep-assuming baseline
    ranks by raw work — shuffling fast-host loot while host0's backlog
    drains at 0.2x."""
    eng = ServingEngine(None, None, n_slots=16, hosts=4,
                        backend=StubModelBackend(), mode="runtime",
                        cost_model=SERVE_COST,
                        host_speed=(0.2, 1.0, 1.0, 1.0),
                        speed_aware=speed_aware, **ENGINE_KW)
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(44):          # straggler: many short requests
        eng.submit(rng.integers(1, 250, 8), 8, home="page0")
        n += 1
    for h in (1, 2):             # fast hosts: their own heavy backlog
        for _ in range(12):
            eng.submit(rng.integers(1, 250, 8), 32, home=f"page{h}")
            n += 1
    # host3: the idle thief making the rescue-vs-shuffle choice
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (speed_aware, len(eng.completed), n)
    return eng


# -- gang split: an oversized gang on a full page group ---------------------

def _run_gang_split(split: bool) -> ServingEngine:
    """4 page groups x 4 slots, HBM budget 4 KV per group: long residents
    fill page0, then a 6-member gang homed there is stuck — the group can
    never hold it whole and every other group's survey refuses the whole
    bubble.  The splitting engine quotes member re-homes across the
    sibling groups against waiting out the residents and buys the split;
    the park-only baseline waits.  ``depth_skew`` is pinned high for BOTH
    variants: the queue-depth rebalance can also expand a stuck gang (a
    different, flat-priced mechanism), and this row isolates the quoted
    split."""
    eng = ServingEngine(None, None, n_slots=16,
                        backend=StubModelBackend(), mode="runtime",
                        cost_model=SERVE_COST, hbm_budget=4.0, kv_bytes=1.0,
                        gang_split=split, depth_skew=99, **ENGINE_KW)
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(4):           # residents occupy page0 for 30 steps
        eng.submit(rng.integers(1, 250, 8), 30, home="page0")
        n += 1
    for _ in range(6):           # the oversized gang, homed to the full group
        eng.submit(rng.integers(1, 250, 8), 24, gang="big", home="page0")
        n += 1
    for p in (1, 2, 3):          # background work on the sibling groups
        for _ in range(2):
            eng.submit(rng.integers(1, 250, 8), 12, home=f"page{p}")
            n += 1
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (split, len(eng.completed), n)
    assert all(0.0 <= u <= eng.hbm_budget + 1e-9 for u in eng.hbm_used), \
        eng.hbm_used
    return eng


# -- HBM pressure: budgets tighter than the slot count ----------------------

def _run_hbm(capacity_aware: bool) -> ServingEngine:
    """2 hosts x 2 page groups x 4 slots, 2 resident KV per group: a fat
    gang pinned to host0 plus lone host1 requests keep every group at its
    budget, so loot placement is capacity-bound, not work-bound.

    The rebalance mode is pinned flat (``dcn_rebalance=False``) for BOTH
    variants: host-local re-spreads partially mask capacity-blind thrash
    (they cheaply re-sort the backlog the blind claims bounced), and this
    row isolates the *capacity* variable — the rebalance-mode contrast is
    ``serve/dcn_rebalance_speedup``'s job."""
    eng = ServingEngine(None, None, n_slots=16, hosts=2,
                        backend=StubModelBackend(), mode="runtime",
                        hbm_budget=2.0, kv_bytes=1.0,
                        capacity_aware=capacity_aware,
                        **{**ENGINE_KW, "dcn_rebalance": False})
    rng = np.random.default_rng(0)
    n = 0
    for _ in range(24):
        eng.submit(rng.integers(1, 250, 8), 10, gang="fat", home="host0")
        n += 1
    for _ in range(6):
        eng.submit(rng.integers(1, 250, 8), 6, prio=1, home="host1")
        n += 1
    eng.run(max_steps=8000)
    assert len(eng.completed) == n, (capacity_aware, len(eng.completed), n)
    assert all(0.0 <= u <= eng.hbm_budget + 1e-9 for u in eng.hbm_used), \
        eng.hbm_used
    return eng


def run(smoke: bool = False) -> list[tuple]:
    rows: list[tuple] = []

    # -- skewed gangs: the steal/rebalance win -------------------------------
    base = _run("admission", SKEWED)
    fast = _run("runtime", SKEWED)
    # scheduling must never change results: same streams in both modes
    assert _streams(base) == _streams(fast), "mode changed decode output"
    speedup = base.steps / fast.steps
    c = fast.counters()
    c["steps_admission"] = base.steps
    rows.append((
        "serve/skewed_steal_speedup", speedup,
        f"steps {base.steps}->{fast.steps} steals={c['steals']}"
        f" rebalances={c['rebalances']} kv_migrations={c['kv_migrations']}",
        c))

    # -- gang churn: regeneration + KV park/splice under migration -----------
    base = _run("admission", CHURN, regen_every=4)
    fast = _run("runtime", CHURN, regen_every=4)
    uninterrupted = _run("runtime", CHURN)
    assert _streams(fast) == _streams(uninterrupted), \
        "regeneration/migration changed decode output"
    c = fast.counters()
    c["steps_admission"] = base.steps
    rows.append((
        "serve/churn_regen_speedup", base.steps / fast.steps,
        f"steps {base.steps}->{fast.steps} kv_parks={c['kv_parks']}"
        f" kv_splices={c['kv_splices']} data_migrations="
        f"{c['data_migrations']}",
        c))

    # -- multi-host skewed pod: DCN-priced vs DCN-naive stealing -------------
    naive = _run_multihost(dcn_aware=False)
    aware = _run_multihost(dcn_aware=True)
    # mispricing the DCN must never change what was decoded
    assert _streams(naive) == _streams(aware), "DCN pricing changed output"
    c = aware.counters()
    c["steps_naive"] = naive.steps
    c["naive_steal_cost"] = naive.counters()["steal_cost"]
    c["naive_kv_host_moves"] = naive.counters()["kv_host_moves"]
    rows.append((
        "serve/multihost_steal_speedup", naive.steps / aware.steps,
        f"steps {naive.steps}->{aware.steps}"
        f" steal_cost {c['naive_steal_cost']}->{c['steal_cost']}"
        f" kv_host_moves {c['naive_kv_host_moves']}->{c['kv_host_moves']}",
        c))

    # -- HBM pressure: capacity-aware vs capacity-blind placement ------------
    blind = _run_hbm(capacity_aware=False)
    awarekv = _run_hbm(capacity_aware=True)
    assert _streams(blind) == _streams(awarekv), \
        "capacity policy changed decode output"
    c = awarekv.counters()
    c["steps_blind"] = blind.steps
    c["blind_steal_cost"] = blind.counters()["steal_cost"]
    c["blind_hbm_refusals"] = blind.counters()["hbm_refusals"]
    rows.append((
        "serve/hbm_pressure_refusal_speedup", blind.steps / awarekv.steps,
        f"steps {blind.steps}->{awarekv.steps}"
        f" steal_cost {c['blind_steal_cost']}->{c['steal_cost']}"
        f" steal_refusals={c['steal_refusals']}"
        f" blind_bounces={c['blind_hbm_refusals']}"
        f" slot_waits={c['hbm_slot_waits']}",
        c))

    # -- DCN-priced rebalancing: host-local vs flat machine-wide -------------
    flat = _run_dcn_rebalance(local=False)
    local = _run_dcn_rebalance(local=True)
    # the rebalance mode must never change what was decoded
    assert _streams(flat) == _streams(local), "rebalance mode changed output"
    c = local.counters()
    c["steps_flat"] = flat.steps
    c["flat_stall_steps"] = flat.counters()["stall_steps"]
    c["flat_rebalances"] = flat.counters()["rebalances"]
    rows.append((
        "serve/dcn_rebalance_speedup", flat.steps / local.steps,
        f"steps {flat.steps}->{local.steps}"
        f" stall {c['flat_stall_steps']}->{c['stall_steps']}"
        f" local_rebalances={c['local_rebalances']}"
        f" host_decode_steps={c['host_decode_steps']}",
        c))

    # -- bandwidth pricing: byte-priced vs byte-naive steal survey -----------
    naive = _run_bandwidth(bw_aware=False)
    aware = _run_bandwidth(bw_aware=True)
    # mispricing the bytes must never change what was decoded
    assert _streams(naive) == _streams(aware), "byte pricing changed output"
    c = aware.counters()
    c["steps_naive"] = naive.steps
    c["naive_steal_cost"] = naive.counters()["steal_cost"]
    c["naive_stall_steps"] = naive.counters()["stall_steps"]
    rows.append((
        "serve/bandwidth_priced_speedup", naive.steps / aware.steps,
        f"steps {naive.steps}->{aware.steps}"
        f" steal_cost {c['naive_steal_cost']}->{c['steal_cost']}"
        f" stall {c['naive_stall_steps']}->{c['stall_steps']}",
        c))

    # -- straggler drain: speed-aware vs lockstep-assuming -------------------
    naive = _run_straggler(speed_aware=False)
    aware = _run_straggler(speed_aware=True)
    # seeing the speed skew must never change what was decoded
    assert _streams(naive) == _streams(aware), "speed model changed output"
    c = aware.counters()
    c["steps_naive"] = naive.steps
    c["naive_steals"] = naive.counters()["steals"]
    c["naive_host_throughput"] = naive.counters()["host_throughput"]
    rows.append((
        "serve/straggler_drain_speedup", naive.steps / aware.steps,
        f"steps {naive.steps}->{aware.steps}"
        f" steals {c['naive_steals']}->{c['steals']}"
        f" host_tp {c['naive_host_throughput']}->{c['host_throughput']}",
        c))

    # -- gang split: quoted member re-homes vs park-and-wait -----------------
    park = _run_gang_split(split=False)
    split = _run_gang_split(split=True)
    assert _streams(park) == _streams(split), "gang split changed output"
    c = split.counters()
    c["steps_park"] = park.steps
    assert c["gang_splits"] >= 1, c          # the mechanism actually fired
    assert park.counters()["gang_splits"] == 0
    rows.append((
        "serve/gang_split_admission_speedup", park.steps / split.steps,
        f"steps {park.steps}->{split.steps}"
        f" gang_splits={c['gang_splits']}"
        f" split_members={c['gang_split_members']}",
        c))
    return rows


def merge_into_json(rows: list[tuple], path: str) -> None:
    """Merge serve/* rows into a schema-1 BENCH json (replacing previous
    serve rows, preserving everything else)."""
    doc = {"schema": 1, "suite": "smoke", "rows": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("schema") == 1, doc.get("schema")
        doc["rows"] = [r for r in doc["rows"]
                       if not r["name"].startswith("serve/")]
    for name, v, d, counters in rows:
        doc["rows"].append({"name": name, "value": round(v, 6),
                            "kind": "speedup", "derived": d,
                            "counters": counters})
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# merged {len(rows)} serve rows into {path}", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    # execution-model knobs (default on; see ENGINE_KW)
    if "--no-per-host-decode" in argv:
        ENGINE_KW["per_host_decode"] = False
    if "--no-wave-prefill" in argv:
        ENGINE_KW["wave_prefill"] = False
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        json_path = argv[i + 1] if i + 1 < len(argv) and \
            not argv[i + 1].startswith("-") else "BENCH_smoke.json"
    elif smoke:
        json_path = "BENCH_smoke.json"
    rows = run(smoke=smoke)
    for name, v, d, _ in rows:
        print(f"{name},{v:.4f},{d}")
    if json_path:
        merge_into_json(rows, json_path)


if __name__ == "__main__":
    main()
